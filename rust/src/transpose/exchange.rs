//! Transpose plans: geometry + buffer metadata for the ROW (X↔Y) and
//! COLUMN (Y↔Z) exchanges, executed over a [`Comm`] with either
//! `alltoallv` (default) or the USEEVEN padded `alltoall` (§3.4).
//!
//! # Topology-aware scheduling
//!
//! The *order* in which peers are serviced is not fixed here: every
//! exchange goes through the collectives layer, which consults the
//! fabric's two-level node map ([`crate::mpi::Hierarchy`]) and services
//! intra-node partners first (`Comm::chunk_peer_offsets`), so inter-node
//! traffic is posted early and its flight time hides behind on-node
//! copies and FFT work. This is safe to do per-exchange because all
//! metadata built in this module is *addressed*, not positional: every
//! [`ChunkMeta`] carries absolute displacements into the full-transpose
//! buffers and every message is routed by `(src, dst, tag)`, so any
//! service order yields bit-identical pencils for every chunk count and
//! every node map.

use crate::fft::{Complex, Real};
use crate::grid::truncation::PruneRule;
use crate::grid::{block_range, Decomp};
use crate::mpi::collectives::WinRecv;
use crate::mpi::{Comm, CopyMode};
use crate::util::timer::{Stage, StageTimer};

use super::pack;

/// Exchange options (the paper's user-tunable knobs).
#[derive(Debug, Clone, Copy)]
pub struct ExchangeOptions {
    /// USEEVEN: pad blocks to a uniform size and use `alltoall` instead of
    /// `alltoallv` — the Cray XT workaround of §3.4 (Schulz).
    pub use_even: bool,
    /// Copy discipline: `SingleCopy` packs intra-node blocks straight
    /// into the peer's pre-registered receive window (one copy);
    /// `Mailbox` keeps the classic pack → mailbox → receive-buffer
    /// chain. Inter-node peers always use the mailbox.
    pub copy: CopyMode,
}

impl Default for ExchangeOptions {
    /// Defaults resolve the copy discipline from `P3DFFT_COPY` (single
    /// copy unless overridden), so env-matrix CI legs flip every
    /// exchange in the suite without per-test plumbing.
    fn default() -> Self {
        ExchangeOptions { use_even: false, copy: CopyMode::from_env() }
    }
}

/// User tag for the inter-node point-to-point leg of the blocking
/// single-copy exchanges (below the collectives' namespaces, above any
/// small user tag).
const XWIN_TAG: u64 = 1 << 39;

/// Plan for the X↔Y transpose within one ROW sub-communicator.
///
/// Forward: spectral X-pencil `[nz][ny_loc][h]` → Y-pencil
/// `[nz][h_loc][ny_glob]`. Backward is the exact inverse.
#[derive(Debug, Clone)]
pub struct TransposeXY {
    /// My row rank (r1) and the row size (M1).
    pub m1: usize,
    pub r1: usize,
    /// Local z extent (shared by the whole row).
    pub nz: usize,
    /// Global packed spectral width and global Y.
    pub h: usize,
    pub ny_glob: usize,
    /// Global spectral-x ranges per row peer.
    pub x_ranges: Vec<std::ops::Range<usize>>,
    /// Global y ranges per row peer.
    pub y_ranges: Vec<std::ops::Range<usize>>,
    /// Truncation: retained spectral-x prefix. When `Some(k)`, every
    /// peer's x range is clamped to `[start, min(end, k))` on the wire;
    /// buffer and pencil shapes are unchanged (pruned destination rows
    /// are simply never written — the backward unpack pre-zeroes them).
    pub kx_keep: Option<usize>,
}

impl TransposeXY {
    /// Build the plan for `world_rank` of `decomp`.
    pub fn new(decomp: &Decomp, world_rank: usize) -> Self {
        let (r1, _r2) = decomp.pgrid.coords(world_rank);
        let m1 = decomp.pgrid.m1;
        let xp = decomp.x_pencil_spec(world_rank);
        TransposeXY {
            m1,
            r1,
            nz: xp.dims[0],
            h: decomp.h(),
            ny_glob: decomp.ny,
            x_ranges: (0..m1).map(|j| block_range(decomp.h(), m1, j)).collect(),
            y_ranges: (0..m1).map(|j| block_range(decomp.ny, m1, j)).collect(),
            kx_keep: None,
        }
    }

    /// Truncated variant: only the retained prefix `0..kx_keep` of the
    /// R2C spectral-x axis travels through the exchange.
    pub fn with_kx_keep(mut self, kx_keep: usize) -> Self {
        self.kx_keep = Some(kx_keep.min(self.h));
        self
    }

    pub fn is_pruned(&self) -> bool {
        self.kx_keep.is_some()
    }

    /// Peer `j`'s spectral-x range, clamped to the retained prefix.
    pub fn x_keep(&self, j: usize) -> std::ops::Range<usize> {
        let r = &self.x_ranges[j];
        match self.kx_keep {
            Some(k) => r.start..r.end.min(k).max(r.start),
            None => r.clone(),
        }
    }

    /// My local y extent (X-pencil) and local spectral width (Y-pencil).
    pub fn ny_loc(&self) -> usize {
        self.y_ranges[self.r1].len()
    }

    pub fn h_loc(&self) -> usize {
        self.x_ranges[self.r1].len()
    }

    /// Retained x rows of my Y-pencil — a prefix of `h_loc` (equals
    /// `h_loc` when unpruned, since the retained x set is a prefix of
    /// the global axis and x ranges are contiguous blocks).
    pub fn hk_loc(&self) -> usize {
        self.x_keep(self.r1).len()
    }

    /// Elements sent to row peer `j` in the forward direction.
    pub fn scount_fwd(&self, j: usize) -> usize {
        self.nz * self.ny_loc() * self.x_keep(j).len()
    }

    /// Elements received from row peer `j` in the forward direction.
    pub fn rcount_fwd(&self, j: usize) -> usize {
        self.nz * self.hk_loc() * self.y_ranges[j].len()
    }

    /// Uniform padded block for USEEVEN (max over all row pairs). Row
    /// uniform even when pruned: every row rank sees the same global
    /// clamped ranges.
    pub fn even_block(&self) -> usize {
        let max_x = (0..self.m1).map(|j| self.x_keep(j).len()).max().unwrap_or(0);
        let max_y = self.y_ranges.iter().map(|r| r.len()).max().unwrap_or(0);
        self.nz * max_x * max_y
    }

    /// Send/recv buffer sizes (elements) for either direction.
    pub fn buf_len(&self, opts: ExchangeOptions) -> usize {
        if opts.use_even {
            self.even_block() * self.m1
        } else {
            // Forward send total == backward recv total and vice versa;
            // both equal nz * ny_loc * h ... take the max of the two.
            let fwd: usize = (0..self.m1).map(|j| self.scount_fwd(j)).sum();
            let bwd: usize = (0..self.m1).map(|j| self.rcount_fwd(j)).sum();
            fwd.max(bwd)
        }
    }

    /// Forward transpose: `input` spectral X-pencil → `output` Y-pencil.
    #[allow(clippy::too_many_arguments)]
    pub fn forward<T: Real>(
        &self,
        row: &Comm,
        input: &[Complex<T>],
        output: &mut [Complex<T>],
        sendbuf: &mut [Complex<T>],
        recvbuf: &mut [Complex<T>],
        opts: ExchangeOptions,
        timer: &mut StageTimer,
    ) {
        debug_assert_eq!(row.size(), self.m1);
        debug_assert_eq!(row.rank(), self.r1);
        let (scounts, sdispls, rcounts, rdispls) = self.meta_fwd(opts);
        if opts.copy == CopyMode::SingleCopy {
            exchange_windowed(
                row,
                sendbuf,
                recvbuf,
                &scounts,
                &sdispls,
                &rcounts,
                &rdispls,
                timer,
                |j, dst| {
                    // Clamped to the retained prefix when pruned (no-op
                    // clamp on the full-grid path).
                    let r = self.x_keep(j);
                    pack::pack_x_to_y(input, self.nz, self.ny_loc(), self.h, r.start, r.end, dst);
                },
            );
        } else {
            timer.time(Stage::Pack, || {
                for j in 0..self.m1 {
                    // Clamped to the retained prefix when pruned (no-op
                    // clamp on the full-grid path).
                    let r = self.x_keep(j);
                    pack::pack_x_to_y(
                        input,
                        self.nz,
                        self.ny_loc(),
                        self.h,
                        r.start,
                        r.end,
                        &mut sendbuf[sdispls[j]..sdispls[j] + self.scount_fwd(j)],
                    );
                }
                note_pack_copies::<T>(row, &scounts);
            });
            timer.time(Stage::Exchange, || {
                self.do_exchange(
                    row, sendbuf, recvbuf, &scounts, &sdispls, &rcounts, &rdispls, opts,
                );
            });
        }
        timer.time(Stage::Unpack, || {
            for j in 0..self.m1 {
                let r = &self.y_ranges[j];
                // Wire blocks carry hk_loc x-rows per z-plane; they land
                // in the prefix rows of the h_loc-strided Y-pencil
                // (identical to unpack_x_to_y when hk_loc == h_loc).
                pack::unpack_x_to_y_pruned_win(
                    &recvbuf[rdispls[j]..rdispls[j] + self.rcount_fwd(j)],
                    self.nz,
                    self.hk_loc(),
                    self.h_loc(),
                    self.ny_glob,
                    r.start,
                    r.end,
                    0,
                    self.nz,
                    output,
                );
            }
        });
    }

    /// Backward transpose: `input` Y-pencil → `output` spectral X-pencil.
    #[allow(clippy::too_many_arguments)]
    pub fn backward<T: Real>(
        &self,
        row: &Comm,
        input: &[Complex<T>],
        output: &mut [Complex<T>],
        sendbuf: &mut [Complex<T>],
        recvbuf: &mut [Complex<T>],
        opts: ExchangeOptions,
        timer: &mut StageTimer,
    ) {
        // Counts reverse: backward scount(j) == forward rcount(j).
        let (rc, rd, sc, sd) = self.meta_fwd(opts);
        let (scounts, sdispls, rcounts, rdispls) = (sc, sd, rc, rd);
        if opts.copy == CopyMode::SingleCopy {
            exchange_windowed(
                row,
                sendbuf,
                recvbuf,
                &scounts,
                &sdispls,
                &rcounts,
                &rdispls,
                timer,
                |j, dst| {
                    let r = &self.y_ranges[j];
                    // Only the retained prefix rows of the Y-pencil
                    // travel back (all rows when unpruned).
                    pack::pack_y_to_x_pruned_win(
                        input,
                        self.nz,
                        self.hk_loc(),
                        self.h_loc(),
                        self.ny_glob,
                        r.start,
                        r.end,
                        0,
                        self.nz,
                        dst,
                    );
                },
            );
        } else {
            timer.time(Stage::Pack, || {
                for j in 0..self.m1 {
                    let r = &self.y_ranges[j];
                    // Only the retained prefix rows of the Y-pencil travel
                    // back (all rows when unpruned).
                    pack::pack_y_to_x_pruned_win(
                        input,
                        self.nz,
                        self.hk_loc(),
                        self.h_loc(),
                        self.ny_glob,
                        r.start,
                        r.end,
                        0,
                        self.nz,
                        &mut sendbuf[sdispls[j]..sdispls[j] + self.rcount_fwd(j)],
                    );
                }
                note_pack_copies::<T>(row, &scounts);
            });
            timer.time(Stage::Exchange, || {
                self.do_exchange(
                    row, sendbuf, recvbuf, &scounts, &sdispls, &rcounts, &rdispls, opts,
                );
            });
        }
        timer.time(Stage::Unpack, || {
            // Pruned x slots are never written by the unpack below —
            // define them as zero so the X-pencil is fully specified.
            if self.is_pruned() {
                output.fill(Complex::zero());
            }
            for j in 0..self.m1 {
                let r = self.x_keep(j);
                pack::unpack_y_to_x(
                    &recvbuf[rdispls[j]..rdispls[j] + self.scount_fwd(j)],
                    self.nz,
                    self.ny_loc(),
                    self.h,
                    r.start,
                    r.end,
                    output,
                );
            }
        });
    }


    /// Non-STRIDE1 forward: XYZ-order spectral X-pencil → XYZ-order
    /// Y-pencil `[nz][ny_glob][h_loc]`. Same counts/volumes as the STRIDE1
    /// path; packs are contiguous slab copies (no local transpose).
    #[allow(clippy::too_many_arguments)]
    pub fn forward_xyz<T: Real>(
        &self,
        row: &Comm,
        input: &[Complex<T>],
        output: &mut [Complex<T>],
        sendbuf: &mut [Complex<T>],
        recvbuf: &mut [Complex<T>],
        opts: ExchangeOptions,
        timer: &mut StageTimer,
    ) {
        // Truncation is gated to the STRIDE1 layout at plan compile time.
        debug_assert!(!self.is_pruned(), "XYZ layout does not support truncation");
        let (scounts, sdispls, rcounts, rdispls) = self.meta_fwd(opts);
        if opts.copy == CopyMode::SingleCopy {
            exchange_windowed(
                row,
                sendbuf,
                recvbuf,
                &scounts,
                &sdispls,
                &rcounts,
                &rdispls,
                timer,
                |j, dst| {
                    let r = &self.x_ranges[j];
                    pack::pack_x_to_y_xyz(input, self.nz, self.ny_loc(), self.h, r.start, r.end, dst);
                },
            );
        } else {
            timer.time(Stage::Pack, || {
                for j in 0..self.m1 {
                    let r = &self.x_ranges[j];
                    pack::pack_x_to_y_xyz(
                        input,
                        self.nz,
                        self.ny_loc(),
                        self.h,
                        r.start,
                        r.end,
                        &mut sendbuf[sdispls[j]..sdispls[j] + self.scount_fwd(j)],
                    );
                }
                note_pack_copies::<T>(row, &scounts);
            });
            timer.time(Stage::Exchange, || {
                self.do_exchange(
                    row, sendbuf, recvbuf, &scounts, &sdispls, &rcounts, &rdispls, opts,
                );
            });
        }
        timer.time(Stage::Unpack, || {
            for j in 0..self.m1 {
                let r = &self.y_ranges[j];
                pack::unpack_x_to_y_xyz(
                    &recvbuf[rdispls[j]..rdispls[j] + self.rcount_fwd(j)],
                    self.nz,
                    self.h_loc(),
                    self.ny_glob,
                    r.start,
                    r.end,
                    output,
                );
            }
        });
    }

    /// Non-STRIDE1 backward: XYZ-order Y-pencil → XYZ-order spectral
    /// X-pencil.
    #[allow(clippy::too_many_arguments)]
    pub fn backward_xyz<T: Real>(
        &self,
        row: &Comm,
        input: &[Complex<T>],
        output: &mut [Complex<T>],
        sendbuf: &mut [Complex<T>],
        recvbuf: &mut [Complex<T>],
        opts: ExchangeOptions,
        timer: &mut StageTimer,
    ) {
        debug_assert!(!self.is_pruned(), "XYZ layout does not support truncation");
        let (rc, rd, sc, sd) = self.meta_fwd(opts);
        let (scounts, sdispls, rcounts, rdispls) = (sc, sd, rc, rd);
        if opts.copy == CopyMode::SingleCopy {
            exchange_windowed(
                row,
                sendbuf,
                recvbuf,
                &scounts,
                &sdispls,
                &rcounts,
                &rdispls,
                timer,
                |j, dst| {
                    let r = &self.y_ranges[j];
                    pack::pack_y_to_x_xyz(input, self.nz, self.h_loc(), self.ny_glob, r.start, r.end, dst);
                },
            );
        } else {
            timer.time(Stage::Pack, || {
                for j in 0..self.m1 {
                    let r = &self.y_ranges[j];
                    pack::pack_y_to_x_xyz(
                        input,
                        self.nz,
                        self.h_loc(),
                        self.ny_glob,
                        r.start,
                        r.end,
                        &mut sendbuf[sdispls[j]..sdispls[j] + self.rcount_fwd(j)],
                    );
                }
                note_pack_copies::<T>(row, &scounts);
            });
            timer.time(Stage::Exchange, || {
                self.do_exchange(
                    row, sendbuf, recvbuf, &scounts, &sdispls, &rcounts, &rdispls, opts,
                );
            });
        }
        timer.time(Stage::Unpack, || {
            for j in 0..self.m1 {
                let r = &self.x_ranges[j];
                pack::unpack_y_to_x_xyz(
                    &recvbuf[rdispls[j]..rdispls[j] + self.scount_fwd(j)],
                    self.nz,
                    self.ny_loc(),
                    self.h,
                    r.start,
                    r.end,
                    output,
                );
            }
        });
    }

    /// counts/displs for the forward direction under `opts`. Exposed to
    /// the crate so fused pair stages (convolve) can double the blocks.
    pub(crate) fn meta_fwd(
        &self,
        opts: ExchangeOptions,
    ) -> (Vec<usize>, Vec<usize>, Vec<usize>, Vec<usize>) {
        meta(
            self.m1,
            opts,
            |j| self.scount_fwd(j),
            |j| self.rcount_fwd(j),
            self.even_block(),
        )
    }

    #[allow(clippy::too_many_arguments)]
    fn do_exchange<T: Real>(
        &self,
        comm: &Comm,
        sendbuf: &[Complex<T>],
        recvbuf: &mut [Complex<T>],
        scounts: &[usize],
        sdispls: &[usize],
        rcounts: &[usize],
        rdispls: &[usize],
        opts: ExchangeOptions,
    ) {
        let even = opts.use_even.then(|| self.even_block());
        exchange_v(comm, sendbuf, recvbuf, scounts, sdispls, rcounts, rdispls, even, opts.copy);
    }
}

/// Plan for the Y↔Z transpose within one COLUMN sub-communicator.
///
/// Forward: Y-pencil `[nz_loc][h_loc][ny_glob]` → Z-pencil
/// `[h_loc][ny2_loc][nz_glob]`.
#[derive(Debug, Clone)]
pub struct TransposeYZ {
    pub m2: usize,
    pub r2: usize,
    /// Local packed-spectral extent (shared by the whole column).
    pub h_loc: usize,
    pub ny_glob: usize,
    pub nz_glob: usize,
    /// Global y ranges per column peer (split by M2).
    pub y_ranges: Vec<std::ops::Range<usize>>,
    /// Global z ranges per column peer.
    pub z_ranges: Vec<std::ops::Range<usize>>,
    /// Truncation: retained transverse (kx, ky) pairs. Both pencils
    /// around this exchange have already transformed x and y, so every
    /// column rank derives the identical mask and only retained pairs'
    /// z-runs travel. Pencil shapes are unchanged; pruned destination
    /// slots are pre-zeroed on unpack.
    pub prune: Option<YzPrune>,
}

/// Compiled prune metadata for a truncated Y↔Z exchange.
#[derive(Debug, Clone)]
pub struct YzPrune {
    /// `keep[x * ny_glob + y]` — retained pairs, sender view (global y;
    /// x is the local spectral row of this column's x block).
    pub keep: Vec<bool>,
    /// `keep_own[x * ny2_loc + yl]` — the same mask windowed to my own
    /// y range (receiver view).
    pub keep_own: Vec<bool>,
    /// `cnt[x * m2 + j]` — retained pairs in peer `j`'s y range for
    /// local x row `x` (the per-plane counts the chunk planner needs).
    pub cnt: Vec<usize>,
}

impl TransposeYZ {
    pub fn new(decomp: &Decomp, world_rank: usize) -> Self {
        let (_r1, r2) = decomp.pgrid.coords(world_rank);
        let m2 = decomp.pgrid.m2;
        let yp = decomp.y_pencil(world_rank);
        TransposeYZ {
            m2,
            r2,
            h_loc: yp.dims[1],
            ny_glob: decomp.ny,
            nz_glob: decomp.nz,
            y_ranges: (0..m2).map(|j| block_range(decomp.ny, m2, j)).collect(),
            z_ranges: (0..m2).map(|j| block_range(decomp.nz, m2, j)).collect(),
            prune: None,
        }
    }

    /// Truncated variant: compile `rule` into per-pair keep masks for
    /// this column's spectral-x block, whose global offset is `x0_glob`
    /// (`y_pencil(rank).offsets[1]`).
    pub fn with_prune(mut self, rule: &PruneRule, x0_glob: usize) -> Self {
        let (h_loc, ny, m2) = (self.h_loc, self.ny_glob, self.m2);
        let mut keep = vec![false; h_loc * ny];
        let mut cnt = vec![0usize; h_loc * m2];
        for x in 0..h_loc {
            for (j, yr) in self.y_ranges.iter().enumerate() {
                for y in yr.clone() {
                    if rule.keep_pair(x0_glob + x, y) {
                        keep[x * ny + y] = true;
                        cnt[x * m2 + j] += 1;
                    }
                }
            }
        }
        let own = self.y_ranges[self.r2].clone();
        let ny2 = own.len();
        let mut keep_own = vec![false; h_loc * ny2];
        for x in 0..h_loc {
            for (yl, y) in own.clone().enumerate() {
                keep_own[x * ny2 + yl] = keep[x * ny + y];
            }
        }
        self.prune = Some(YzPrune { keep, keep_own, cnt });
        self
    }

    pub fn is_pruned(&self) -> bool {
        self.prune.is_some()
    }

    pub fn nz_loc(&self) -> usize {
        self.z_ranges[self.r2].len()
    }

    pub fn ny2_loc(&self) -> usize {
        self.y_ranges[self.r2].len()
    }

    /// Retained (x, y) pairs for local x row `x` going to peer `j`.
    fn pairs_at(&self, x: usize, j: usize) -> usize {
        match &self.prune {
            Some(p) => p.cnt[x * self.m2 + j],
            None => self.y_ranges[j].len(),
        }
    }

    /// Total retained pairs shipped to peer `j` (all pairs when
    /// unpruned).
    pub fn pairs_to(&self, j: usize) -> usize {
        match &self.prune {
            Some(p) => (0..self.h_loc).map(|x| p.cnt[x * self.m2 + j]).sum(),
            None => self.h_loc * self.y_ranges[j].len(),
        }
    }

    pub fn scount_fwd(&self, j: usize) -> usize {
        self.pairs_to(j) * self.nz_loc()
    }

    pub fn rcount_fwd(&self, j: usize) -> usize {
        // Peer j holds the same x block and the same mask, so the pairs
        // it retains for *my* y range equal pairs_to(r2).
        self.pairs_to(self.r2) * self.z_ranges[j].len()
    }

    /// Uniform padded block for USEEVEN. Column uniform even when
    /// pruned: every column rank computes the identical mask.
    pub fn even_block(&self) -> usize {
        let max_pairs = (0..self.m2).map(|j| self.pairs_to(j)).max().unwrap_or(0);
        let max_z = self.z_ranges.iter().map(|r| r.len()).max().unwrap_or(0);
        max_pairs * max_z
    }

    pub fn buf_len(&self, opts: ExchangeOptions) -> usize {
        if opts.use_even {
            self.even_block() * self.m2
        } else {
            let fwd: usize = (0..self.m2).map(|j| self.scount_fwd(j)).sum();
            let bwd: usize = (0..self.m2).map(|j| self.rcount_fwd(j)).sum();
            fwd.max(bwd)
        }
    }

    /// Forward transpose: Y-pencil → Z-pencil.
    #[allow(clippy::too_many_arguments)]
    pub fn forward<T: Real>(
        &self,
        col: &Comm,
        input: &[Complex<T>],
        output: &mut [Complex<T>],
        sendbuf: &mut [Complex<T>],
        recvbuf: &mut [Complex<T>],
        opts: ExchangeOptions,
        timer: &mut StageTimer,
    ) {
        debug_assert_eq!(col.size(), self.m2);
        debug_assert_eq!(col.rank(), self.r2);
        let (scounts, sdispls, rcounts, rdispls) = self.meta_fwd(opts);
        let pack_to = |j: usize, dst: &mut [Complex<T>]| {
            let r = &self.y_ranges[j];
            match &self.prune {
                Some(pr) => pack::pack_y_to_z_pruned_win(
                    input,
                    self.nz_loc(),
                    self.h_loc,
                    self.ny_glob,
                    r.start,
                    r.end,
                    0,
                    self.h_loc,
                    &pr.keep,
                    dst,
                ),
                None => pack::pack_y_to_z(
                    input,
                    self.nz_loc(),
                    self.h_loc,
                    self.ny_glob,
                    r.start,
                    r.end,
                    dst,
                ),
            }
        };
        if opts.copy == CopyMode::SingleCopy {
            exchange_windowed(
                col, sendbuf, recvbuf, &scounts, &sdispls, &rcounts, &rdispls, timer, pack_to,
            );
        } else {
            timer.time(Stage::Pack, || {
                for j in 0..self.m2 {
                    pack_to(j, &mut sendbuf[sdispls[j]..sdispls[j] + self.scount_fwd(j)]);
                }
                note_pack_copies::<T>(col, &scounts);
            });
            timer.time(Stage::Exchange, || {
                self.do_exchange(
                    col, sendbuf, recvbuf, &scounts, &sdispls, &rcounts, &rdispls, opts,
                );
            });
        }
        timer.time(Stage::Unpack, || {
            // Pruned pairs are never written below — define the whole
            // Z-pencil so their slots hold exact zeros.
            if self.is_pruned() {
                output.fill(Complex::zero());
            }
            for j in 0..self.m2 {
                let r = &self.z_ranges[j];
                let buf = &recvbuf[rdispls[j]..rdispls[j] + self.rcount_fwd(j)];
                match &self.prune {
                    Some(pr) => pack::unpack_y_to_z_pruned_win(
                        buf,
                        self.h_loc,
                        self.ny2_loc(),
                        self.nz_glob,
                        r.start,
                        r.end,
                        0,
                        self.h_loc,
                        &pr.keep_own,
                        output,
                    ),
                    None => pack::unpack_y_to_z(
                        buf,
                        self.h_loc,
                        self.ny2_loc(),
                        self.nz_glob,
                        r.start,
                        r.end,
                        output,
                    ),
                }
            }
        });
    }

    /// Backward transpose: Z-pencil → Y-pencil.
    #[allow(clippy::too_many_arguments)]
    pub fn backward<T: Real>(
        &self,
        col: &Comm,
        input: &[Complex<T>],
        output: &mut [Complex<T>],
        sendbuf: &mut [Complex<T>],
        recvbuf: &mut [Complex<T>],
        opts: ExchangeOptions,
        timer: &mut StageTimer,
    ) {
        let (rc, rd, sc, sd) = self.meta_fwd(opts);
        let (scounts, sdispls, rcounts, rdispls) = (sc, sd, rc, rd);
        let pack_to = |j: usize, dst: &mut [Complex<T>]| {
            let r = &self.z_ranges[j];
            match &self.prune {
                Some(pr) => pack::pack_z_to_y_pruned_win(
                    input,
                    self.h_loc,
                    self.ny2_loc(),
                    self.nz_glob,
                    r.start,
                    r.end,
                    0,
                    self.h_loc,
                    &pr.keep_own,
                    dst,
                ),
                None => pack::pack_z_to_y(
                    input,
                    self.h_loc,
                    self.ny2_loc(),
                    self.nz_glob,
                    r.start,
                    r.end,
                    dst,
                ),
            }
        };
        if opts.copy == CopyMode::SingleCopy {
            exchange_windowed(
                col, sendbuf, recvbuf, &scounts, &sdispls, &rcounts, &rdispls, timer, pack_to,
            );
        } else {
            timer.time(Stage::Pack, || {
                for j in 0..self.m2 {
                    pack_to(j, &mut sendbuf[sdispls[j]..sdispls[j] + self.rcount_fwd(j)]);
                }
                note_pack_copies::<T>(col, &scounts);
            });
            timer.time(Stage::Exchange, || {
                self.do_exchange(
                    col, sendbuf, recvbuf, &scounts, &sdispls, &rcounts, &rdispls, opts,
                );
            });
        }
        timer.time(Stage::Unpack, || {
            if self.is_pruned() {
                output.fill(Complex::zero());
            }
            for j in 0..self.m2 {
                let r = &self.y_ranges[j];
                let buf = &recvbuf[rdispls[j]..rdispls[j] + self.scount_fwd(j)];
                match &self.prune {
                    Some(pr) => pack::unpack_z_to_y_pruned_win(
                        buf,
                        self.nz_loc(),
                        self.h_loc,
                        self.ny_glob,
                        r.start,
                        r.end,
                        0,
                        self.h_loc,
                        &pr.keep,
                        output,
                    ),
                    None => pack::unpack_z_to_y(
                        buf,
                        self.nz_loc(),
                        self.h_loc,
                        self.ny_glob,
                        r.start,
                        r.end,
                        output,
                    ),
                }
            }
        });
    }


    /// Non-STRIDE1 forward: XYZ-order Y-pencil `[nz_loc][ny_glob][h_loc]`
    /// → XYZ-order Z-pencil `[nz_glob][ny2_loc][h_loc]`.
    #[allow(clippy::too_many_arguments)]
    pub fn forward_xyz<T: Real>(
        &self,
        col: &Comm,
        input: &[Complex<T>],
        output: &mut [Complex<T>],
        sendbuf: &mut [Complex<T>],
        recvbuf: &mut [Complex<T>],
        opts: ExchangeOptions,
        timer: &mut StageTimer,
    ) {
        debug_assert!(!self.is_pruned(), "XYZ layout does not support truncation");
        let (scounts, sdispls, rcounts, rdispls) = self.meta_fwd(opts);
        if opts.copy == CopyMode::SingleCopy {
            // Receive **in place**: the XYZ Z-pencil unpack is one
            // contiguous z-slab copy per peer, so the windows are
            // registered straight over `output` at the true slab offsets
            // — the unpack stage disappears and `recvbuf` is never
            // touched (callers may pass it empty on this path).
            let plane = self.ny2_loc() * self.h_loc;
            let odispls: Vec<usize> =
                (0..self.m2).map(|j| self.z_ranges[j].start * plane).collect();
            exchange_windowed(
                col,
                sendbuf,
                output,
                &scounts,
                &sdispls,
                &rcounts,
                &odispls,
                timer,
                |j, dst| {
                    let r = &self.y_ranges[j];
                    pack::pack_y_to_z_xyz(
                        input,
                        self.nz_loc(),
                        self.h_loc,
                        self.ny_glob,
                        r.start,
                        r.end,
                        dst,
                    );
                },
            );
            return;
        }
        timer.time(Stage::Pack, || {
            for j in 0..self.m2 {
                let r = &self.y_ranges[j];
                pack::pack_y_to_z_xyz(
                    input,
                    self.nz_loc(),
                    self.h_loc,
                    self.ny_glob,
                    r.start,
                    r.end,
                    &mut sendbuf[sdispls[j]..sdispls[j] + self.scount_fwd(j)],
                );
            }
            note_pack_copies::<T>(col, &scounts);
        });
        timer.time(Stage::Exchange, || {
            self.do_exchange(col, sendbuf, recvbuf, &scounts, &sdispls, &rcounts, &rdispls, opts);
        });
        timer.time(Stage::Unpack, || {
            for j in 0..self.m2 {
                let r = &self.z_ranges[j];
                pack::unpack_y_to_z_xyz(
                    &recvbuf[rdispls[j]..rdispls[j] + self.rcount_fwd(j)],
                    self.h_loc,
                    self.ny2_loc(),
                    self.nz_glob,
                    r.start,
                    r.end,
                    output,
                );
            }
        });
    }

    /// Non-STRIDE1 backward: XYZ-order Z-pencil → XYZ-order Y-pencil.
    #[allow(clippy::too_many_arguments)]
    pub fn backward_xyz<T: Real>(
        &self,
        col: &Comm,
        input: &[Complex<T>],
        output: &mut [Complex<T>],
        sendbuf: &mut [Complex<T>],
        recvbuf: &mut [Complex<T>],
        opts: ExchangeOptions,
        timer: &mut StageTimer,
    ) {
        debug_assert!(!self.is_pruned(), "XYZ layout does not support truncation");
        let (rc, rd, sc, sd) = self.meta_fwd(opts);
        let (scounts, sdispls, rcounts, rdispls) = (sc, sd, rc, rd);
        if opts.copy == CopyMode::SingleCopy {
            exchange_windowed(
                col,
                sendbuf,
                recvbuf,
                &scounts,
                &sdispls,
                &rcounts,
                &rdispls,
                timer,
                |j, dst| {
                    let r = &self.z_ranges[j];
                    pack::pack_z_to_y_xyz(
                        input,
                        self.h_loc,
                        self.ny2_loc(),
                        self.nz_glob,
                        r.start,
                        r.end,
                        dst,
                    );
                },
            );
        } else {
            timer.time(Stage::Pack, || {
                for j in 0..self.m2 {
                    let r = &self.z_ranges[j];
                    pack::pack_z_to_y_xyz(
                        input,
                        self.h_loc,
                        self.ny2_loc(),
                        self.nz_glob,
                        r.start,
                        r.end,
                        &mut sendbuf[sdispls[j]..sdispls[j] + self.rcount_fwd(j)],
                    );
                }
                note_pack_copies::<T>(col, &scounts);
            });
            timer.time(Stage::Exchange, || {
                self.do_exchange(
                    col, sendbuf, recvbuf, &scounts, &sdispls, &rcounts, &rdispls, opts,
                );
            });
        }
        timer.time(Stage::Unpack, || {
            for j in 0..self.m2 {
                let r = &self.y_ranges[j];
                pack::unpack_z_to_y_xyz(
                    &recvbuf[rdispls[j]..rdispls[j] + self.scount_fwd(j)],
                    self.nz_loc(),
                    self.h_loc,
                    self.ny_glob,
                    r.start,
                    r.end,
                    output,
                );
            }
        });
    }

    pub(crate) fn meta_fwd(
        &self,
        opts: ExchangeOptions,
    ) -> (Vec<usize>, Vec<usize>, Vec<usize>, Vec<usize>) {
        meta(
            self.m2,
            opts,
            |j| self.scount_fwd(j),
            |j| self.rcount_fwd(j),
            self.even_block(),
        )
    }

    #[allow(clippy::too_many_arguments)]
    fn do_exchange<T: Real>(
        &self,
        comm: &Comm,
        sendbuf: &[Complex<T>],
        recvbuf: &mut [Complex<T>],
        scounts: &[usize],
        sdispls: &[usize],
        rcounts: &[usize],
        rdispls: &[usize],
        opts: ExchangeOptions,
    ) {
        let even = opts.use_even.then(|| self.even_block());
        exchange_v(comm, sendbuf, recvbuf, scounts, sdispls, rcounts, rdispls, even, opts.copy);
    }
}

/// Charge one full pack sweep (`sum(scounts)` elements) to this rank's
/// copy counter — the mailbox path's first copy. The windowed path
/// accounts per block inside [`exchange_windowed`] instead.
fn note_pack_copies<T: Real>(comm: &Comm, scounts: &[usize]) {
    let total: usize = scounts.iter().sum();
    comm.note_copied((total * std::mem::size_of::<Complex<T>>()) as u64);
}

/// Shared body of the blocking single-copy exchanges: register the intra
/// peers' receive windows, then pack every peer's block — *into the
/// peer's window* for intra-node peers (the single copy; the mailbox
/// discipline pays pack + insert + extract), straight into the own
/// receive region for the self block, and into `sendbuf` for inter-node
/// peers, whose mailbox leg is kept verbatim. `pack(j, dst)` must write
/// peer `j`'s `scounts[j]`-element block; it runs against a window view
/// exactly as it runs against a `sendbuf` slice, which is what makes the
/// two copy modes bit-identical by construction.
///
/// Deadlock-freedom: registration never blocks and precedes every
/// blocking call on every rank, fills wait only on registration, the
/// mailbox sends are buffered, and awaits wait only on fills — so the
/// wait graph is acyclic.
#[allow(clippy::too_many_arguments)]
fn exchange_windowed<T: Real>(
    comm: &Comm,
    sendbuf: &mut [Complex<T>],
    recvbuf: &mut [Complex<T>],
    scounts: &[usize],
    sdispls: &[usize],
    rcounts: &[usize],
    rdispls: &[usize],
    timer: &mut StageTimer,
    mut pack: impl FnMut(usize, &mut [Complex<T>]),
) {
    let p = scounts.len();
    let me = comm.rank();
    let elem = std::mem::size_of::<Complex<T>>();
    debug_assert_eq!(scounts[me], rcounts[me], "self block must be symmetric");
    let mut win = WinRecv::new(comm, &mut *recvbuf);
    for i in 0..p {
        if i != me && comm.peer_is_intra(i) {
            win.register(i, 0, rdispls[i], rcounts[i]);
        }
    }
    timer.time(Stage::Pack, || {
        for j in 0..p {
            let n = scounts[j];
            if j == me {
                // One pack straight into my own receive region; the
                // mailbox path pays pack + self memcpy.
                pack(j, win.slice_mut(rdispls[me], n));
                comm.note_copied((n * elem) as u64);
                comm.note_elided((n * elem) as u64);
            } else if comm.peer_is_intra(j) {
                comm.fill_window_with(j, 0, n, |w: &mut [Complex<T>]| pack(j, w));
                comm.note_elided((2 * n * elem) as u64);
            } else {
                pack(j, &mut sendbuf[sdispls[j]..sdispls[j] + n]);
                comm.note_copied((n * elem) as u64);
            }
        }
    });
    timer.time(Stage::Exchange, || {
        for j in 0..p {
            if j != me && !comm.peer_is_intra(j) {
                comm.send(j, XWIN_TAG, &sendbuf[sdispls[j]..sdispls[j] + scounts[j]]);
            }
        }
        for i in 0..p {
            if i != me && !comm.peer_is_intra(i) {
                win.recv_into(i, XWIN_TAG, rdispls[i], rcounts[i]);
            }
        }
        for i in 0..p {
            if i != me && comm.peer_is_intra(i) {
                win.await_win(i, 0);
            }
        }
        comm.barrier();
    });
    drop(win);
}

/// One blocking all-to-all exchange leg over explicit counts and
/// absolute displacements: the padded `alltoall` when `even_block` is
/// `Some` (USEEVEN), `alltoallv` otherwise — each routed through the
/// windowed collective when `copy` is `SingleCopy`. This is the body
/// both transposes share, exposed so stages that fuse two fields into
/// one exchange (the convolve pair stages) can drive it with doubled
/// blocks.
#[allow(clippy::too_many_arguments)]
pub fn exchange_v<T: Real>(
    comm: &Comm,
    sendbuf: &[Complex<T>],
    recvbuf: &mut [Complex<T>],
    scounts: &[usize],
    sdispls: &[usize],
    rcounts: &[usize],
    rdispls: &[usize],
    even_block: Option<usize>,
    copy: CopyMode,
) {
    let p = scounts.len();
    match even_block {
        Some(b) => {
            let len = b * p;
            match copy {
                CopyMode::SingleCopy => {
                    comm.alltoall_windowed(&sendbuf[..len], &mut recvbuf[..len], b)
                }
                CopyMode::Mailbox => comm.alltoall(&sendbuf[..len], &mut recvbuf[..len], b),
            }
        }
        None => {
            let slen = sdispls[p - 1] + scounts[p - 1];
            let rlen = rdispls[p - 1] + rcounts[p - 1];
            match copy {
                CopyMode::SingleCopy => comm.alltoallv_windowed(
                    &sendbuf[..slen],
                    scounts,
                    sdispls,
                    &mut recvbuf[..rlen],
                    rcounts,
                    rdispls,
                ),
                CopyMode::Mailbox => comm.alltoallv(
                    &sendbuf[..slen],
                    scounts,
                    sdispls,
                    &mut recvbuf[..rlen],
                    rcounts,
                    rdispls,
                ),
            }
        }
    }
}

/// Exchange metadata for `E` same-shape fields fused into ONE
/// `alltoall(v)`: every per-peer block of the single-field forward
/// metadata is stacked `E` times, field `f` of peer `j` occupying
/// `[sde[j] + f·s_off[j], sde[j] + f·s_off[j] + sc[j])` of the send
/// buffer. The per-field stride `s_off[j]` is `even_block` under USEEVEN
/// (every field stays block-aligned inside the padded `alltoall` slot of
/// `E·even_block`) and the true count otherwise (the `alltoallv` payload
/// stays dense). `E == 2` reproduces the convolve pair-block wire format
/// exactly; the serve-layer coalescer drives it at the lane width.
#[derive(Debug, Clone)]
pub struct EFieldMeta {
    /// Fields fused per exchange window.
    pub e: usize,
    /// Single-field per-peer counts (one field's block length).
    pub sc: Vec<usize>,
    pub rc: Vec<usize>,
    /// E-field wire counts/displacements handed to [`exchange_v`].
    pub sce: Vec<usize>,
    pub sde: Vec<usize>,
    pub rce: Vec<usize>,
    pub rde: Vec<usize>,
    /// Per-field displacement stride inside one peer's fused block.
    pub s_off: Vec<usize>,
    pub r_off: Vec<usize>,
    /// E-field padded block for the USEEVEN `alltoall`.
    pub evene: Option<usize>,
    /// Copy discipline the fused exchange runs under (from the options
    /// it was compiled with), so coalesced E-field windows ride the
    /// single-copy path too.
    pub copy: CopyMode,
}

impl EFieldMeta {
    /// Fuse the single-field metadata tuple `(sc, sd, rc, rd)` (as
    /// returned by the transposes' `meta_fwd`) into `e`-field blocks.
    pub fn new(
        (sc, sd, rc, rd): (Vec<usize>, Vec<usize>, Vec<usize>, Vec<usize>),
        opts: ExchangeOptions,
        even_block: usize,
        e: usize,
    ) -> Self {
        let p = sc.len();
        let sce = sc.iter().map(|c| e * c).collect();
        let rce = rc.iter().map(|c| e * c).collect();
        let sde = sd.iter().map(|d| e * d).collect();
        let rde = rd.iter().map(|d| e * d).collect();
        let (s_off, r_off) = if opts.use_even {
            (vec![even_block; p], vec![even_block; p])
        } else {
            (sc.clone(), rc.clone())
        };
        let evene = opts.use_even.then(|| e * even_block);
        EFieldMeta { e, sc, rc, sce, sde, rce, rde, s_off, r_off, evene, copy: opts.copy }
    }

    /// Send-buffer range of field `f`'s block for peer `j`.
    pub fn send_range(&self, j: usize, f: usize) -> std::ops::Range<usize> {
        debug_assert!(f < self.e);
        let b = self.sde[j] + f * self.s_off[j];
        b..b + self.sc[j]
    }

    /// Recv-buffer range of field `f`'s block from peer `j`.
    pub fn recv_range(&self, j: usize, f: usize) -> std::ops::Range<usize> {
        debug_assert!(f < self.e);
        let b = self.rde[j] + f * self.r_off[j];
        b..b + self.rc[j]
    }

    /// Send/recv buffer length (elements) the fused exchange needs.
    pub fn buf_len(&self) -> usize {
        match self.evene {
            Some(b) => b * self.sc.len(),
            None => {
                let s: usize = self.sce.iter().sum();
                let r: usize = self.rce.iter().sum();
                s.max(r)
            }
        }
    }

    /// Execute the fused exchange over `comm`. Callers pack the full
    /// fused volume into `sendbuf` first, so the pack's copy cost is
    /// charged here on their behalf (both copy modes pay it — the fused
    /// layout interleaves fields per peer, so even the single-copy path
    /// stages through the send buffer and elides only the mailbox hop).
    pub fn exchange<T: Real>(
        &self,
        comm: &Comm,
        sendbuf: &[Complex<T>],
        recvbuf: &mut [Complex<T>],
    ) {
        let total: usize = self.sce.iter().sum();
        comm.note_copied((total * std::mem::size_of::<Complex<T>>()) as u64);
        exchange_v(
            comm, sendbuf, recvbuf, &self.sce, &self.sde, &self.rce, &self.rde, self.evene,
            self.copy,
        );
    }
}

impl TransposeXY {
    /// Forward E-field fused metadata (see [`EFieldMeta`]).
    pub fn efield_meta_fwd(&self, opts: ExchangeOptions, e: usize) -> EFieldMeta {
        EFieldMeta::new(self.meta_fwd(opts), opts, self.even_block(), e)
    }
}

impl TransposeYZ {
    /// Forward E-field fused metadata (see [`EFieldMeta`]).
    pub fn efield_meta_fwd(&self, opts: ExchangeOptions, e: usize) -> EFieldMeta {
        EFieldMeta::new(self.meta_fwd(opts), opts, self.even_block(), e)
    }
}

/// Per-chunk exchange metadata for the overlap executor: one
/// invariant-axis window plus per-peer counts with *absolute*
/// displacements into the full-transpose send/recv buffers. Chunk windows
/// are disjoint, so chunk `i+1` can be packed while chunk `i` is still in
/// flight and chunk `i-1` is being unpacked.
///
/// On the single-copy path the absolute `rdispls` double as receive-window
/// offsets: each chunk registers `(rdispls[j], rcounts[j])` slices of the
/// recv-side buffer as fabric windows, so intra-node senders pack straight
/// into them and the chunked path elides its mailbox copies too.
#[derive(Debug, Clone)]
pub struct ChunkMeta {
    /// The invariant-axis window this chunk covers (z for X↔Y, spectral x
    /// for Y↔Z).
    pub range: std::ops::Range<usize>,
    pub scounts: Vec<usize>,
    pub sdispls: Vec<usize>,
    pub rcounts: Vec<usize>,
    pub rdispls: Vec<usize>,
}

/// A chunked view of one transpose direction: the invariant axis split
/// into at most `k` block ranges (uneven tails allowed; `k` is clamped to
/// the axis extent so no chunk is empty).
#[derive(Debug, Clone)]
pub struct ChunkPlan {
    pub chunks: Vec<ChunkMeta>,
}

impl ChunkPlan {
    pub fn len(&self) -> usize {
        self.chunks.len()
    }

    pub fn is_empty(&self) -> bool {
        self.chunks.is_empty()
    }
}

/// Build a chunk plan from per-peer counts *per invariant-axis plane*.
/// The closures receive `(plane, peer)` — pruned Y↔Z exchanges have
/// genuinely non-uniform planes (each spectral-x row retains a
/// different number of (kx, ky) pairs), so displacements are running
/// prefix sums over planes; for plane-uniform closures this reproduces
/// the `range.start * plane_total` arithmetic exactly.
fn chunk_plan(
    axis_len: usize,
    k: usize,
    p: usize,
    s_unit: impl Fn(usize, usize) -> usize,
    r_unit: impl Fn(usize, usize) -> usize,
) -> ChunkPlan {
    let k = k.clamp(1, axis_len.max(1));
    let mut chunks = Vec::with_capacity(k);
    let (mut soff0, mut roff0) = (0usize, 0usize);
    for c in 0..k {
        let range = block_range(axis_len, k, c);
        let mut scounts = Vec::with_capacity(p);
        let mut sdispls = Vec::with_capacity(p);
        let mut rcounts = Vec::with_capacity(p);
        let mut rdispls = Vec::with_capacity(p);
        let (mut soff, mut roff) = (soff0, roff0);
        for j in 0..p {
            let sc: usize = range.clone().map(|plane| s_unit(plane, j)).sum();
            let rc: usize = range.clone().map(|plane| r_unit(plane, j)).sum();
            scounts.push(sc);
            sdispls.push(soff);
            soff += sc;
            rcounts.push(rc);
            rdispls.push(roff);
            roff += rc;
        }
        (soff0, roff0) = (soff, roff);
        chunks.push(ChunkMeta { range, scounts, sdispls, rcounts, rdispls });
    }
    ChunkPlan { chunks }
}

impl TransposeXY {
    /// Chunked forward view: z-slabs, per-peer counts scaled per plane.
    pub fn chunks_fwd(&self, k: usize) -> ChunkPlan {
        chunk_plan(
            self.nz,
            k,
            self.m1,
            |_z, j| self.ny_loc() * self.x_keep(j).len(),
            |_z, j| self.hk_loc() * self.y_ranges[j].len(),
        )
    }

    /// Chunked backward view (send/recv roles of the forward swapped).
    pub fn chunks_bwd(&self, k: usize) -> ChunkPlan {
        chunk_plan(
            self.nz,
            k,
            self.m1,
            |_z, j| self.hk_loc() * self.y_ranges[j].len(),
            |_z, j| self.ny_loc() * self.x_keep(j).len(),
        )
    }

    /// Pack the forward send block for row peer `j`, z-window `[za, zb)`.
    pub fn pack_fwd_win<T: Real>(
        &self,
        input: &[Complex<T>],
        j: usize,
        za: usize,
        zb: usize,
        out: &mut [Complex<T>],
    ) {
        let r = self.x_keep(j);
        pack::pack_x_to_y_win(input, self.nz, self.ny_loc(), self.h, r.start, r.end, za, zb, out);
    }

    /// Unpack the forward recv block from row peer `j`, z-window `[za, zb)`.
    pub fn unpack_fwd_win<T: Real>(
        &self,
        buf: &[Complex<T>],
        j: usize,
        za: usize,
        zb: usize,
        output: &mut [Complex<T>],
    ) {
        let r = &self.y_ranges[j];
        pack::unpack_x_to_y_pruned_win(
            buf,
            self.nz,
            self.hk_loc(),
            self.h_loc(),
            self.ny_glob,
            r.start,
            r.end,
            za,
            zb,
            output,
        );
    }

    /// Pack the backward send block for row peer `j`, z-window `[za, zb)`.
    pub fn pack_bwd_win<T: Real>(
        &self,
        input: &[Complex<T>],
        j: usize,
        za: usize,
        zb: usize,
        out: &mut [Complex<T>],
    ) {
        let r = &self.y_ranges[j];
        pack::pack_y_to_x_pruned_win(
            input,
            self.nz,
            self.hk_loc(),
            self.h_loc(),
            self.ny_glob,
            r.start,
            r.end,
            za,
            zb,
            out,
        );
    }

    /// Unpack the backward recv block from row peer `j`, z-window `[za, zb)`.
    /// When pruned, the caller pre-zeroes the X-pencil: only the
    /// retained x prefix is written back.
    pub fn unpack_bwd_win<T: Real>(
        &self,
        buf: &[Complex<T>],
        j: usize,
        za: usize,
        zb: usize,
        output: &mut [Complex<T>],
    ) {
        let r = self.x_keep(j);
        pack::unpack_y_to_x_win(buf, self.nz, self.ny_loc(), self.h, r.start, r.end, za, zb, output);
    }
}

impl TransposeYZ {
    /// Chunked forward view: spectral-x slabs. Pruned plans have
    /// genuinely per-plane counts (each x row retains a different pair
    /// set), which the generalized planner accumulates exactly.
    pub fn chunks_fwd(&self, k: usize) -> ChunkPlan {
        chunk_plan(
            self.h_loc,
            k,
            self.m2,
            |x, j| self.pairs_at(x, j) * self.nz_loc(),
            |x, j| self.pairs_at(x, self.r2) * self.z_ranges[j].len(),
        )
    }

    /// Chunked backward view.
    pub fn chunks_bwd(&self, k: usize) -> ChunkPlan {
        chunk_plan(
            self.h_loc,
            k,
            self.m2,
            |x, j| self.pairs_at(x, self.r2) * self.z_ranges[j].len(),
            |x, j| self.pairs_at(x, j) * self.nz_loc(),
        )
    }

    /// Pack the forward send block for column peer `j`, x-window `[xa, xb)`.
    pub fn pack_fwd_win<T: Real>(
        &self,
        input: &[Complex<T>],
        j: usize,
        xa: usize,
        xb: usize,
        out: &mut [Complex<T>],
    ) {
        let r = &self.y_ranges[j];
        match &self.prune {
            Some(pr) => pack::pack_y_to_z_pruned_win(
                input,
                self.nz_loc(),
                self.h_loc,
                self.ny_glob,
                r.start,
                r.end,
                xa,
                xb,
                &pr.keep,
                out,
            ),
            None => pack::pack_y_to_z_win(
                input,
                self.nz_loc(),
                self.h_loc,
                self.ny_glob,
                r.start,
                r.end,
                xa,
                xb,
                out,
            ),
        }
    }

    /// Unpack the forward recv block from column peer `j`, x-window `[xa, xb)`.
    /// When pruned, the caller pre-zeroes the Z-pencil: only retained
    /// pairs are written.
    pub fn unpack_fwd_win<T: Real>(
        &self,
        buf: &[Complex<T>],
        j: usize,
        xa: usize,
        xb: usize,
        output: &mut [Complex<T>],
    ) {
        let r = &self.z_ranges[j];
        match &self.prune {
            Some(pr) => pack::unpack_y_to_z_pruned_win(
                buf,
                self.h_loc,
                self.ny2_loc(),
                self.nz_glob,
                r.start,
                r.end,
                xa,
                xb,
                &pr.keep_own,
                output,
            ),
            None => pack::unpack_y_to_z_win(
                buf,
                self.h_loc,
                self.ny2_loc(),
                self.nz_glob,
                r.start,
                r.end,
                xa,
                xb,
                output,
            ),
        }
    }

    /// Pack the backward send block for column peer `j`, x-window `[xa, xb)`.
    pub fn pack_bwd_win<T: Real>(
        &self,
        input: &[Complex<T>],
        j: usize,
        xa: usize,
        xb: usize,
        out: &mut [Complex<T>],
    ) {
        let r = &self.z_ranges[j];
        match &self.prune {
            Some(pr) => pack::pack_z_to_y_pruned_win(
                input,
                self.h_loc,
                self.ny2_loc(),
                self.nz_glob,
                r.start,
                r.end,
                xa,
                xb,
                &pr.keep_own,
                out,
            ),
            None => pack::pack_z_to_y_win(
                input,
                self.h_loc,
                self.ny2_loc(),
                self.nz_glob,
                r.start,
                r.end,
                xa,
                xb,
                out,
            ),
        }
    }

    /// Unpack the backward recv block from column peer `j`, x-window `[xa, xb)`.
    /// When pruned, the caller pre-zeroes the Y-pencil.
    pub fn unpack_bwd_win<T: Real>(
        &self,
        buf: &[Complex<T>],
        j: usize,
        xa: usize,
        xb: usize,
        output: &mut [Complex<T>],
    ) {
        let r = &self.y_ranges[j];
        match &self.prune {
            Some(pr) => pack::unpack_z_to_y_pruned_win(
                buf,
                self.nz_loc(),
                self.h_loc,
                self.ny_glob,
                r.start,
                r.end,
                xa,
                xb,
                &pr.keep,
                output,
            ),
            None => pack::unpack_z_to_y_win(
                buf,
                self.nz_loc(),
                self.h_loc,
                self.ny_glob,
                r.start,
                r.end,
                xa,
                xb,
                output,
            ),
        }
    }
}

/// Shared counts/displacements builder. Under USEEVEN every displacement
/// advances by the uniform padded block (contents beyond the true count
/// are don't-care padding, exactly as in the paper's workaround).
fn meta(
    p: usize,
    opts: ExchangeOptions,
    scount: impl Fn(usize) -> usize,
    rcount: impl Fn(usize) -> usize,
    even_block: usize,
) -> (Vec<usize>, Vec<usize>, Vec<usize>, Vec<usize>) {
    let mut scounts = Vec::with_capacity(p);
    let mut rcounts = Vec::with_capacity(p);
    let mut sdispls = Vec::with_capacity(p);
    let mut rdispls = Vec::with_capacity(p);
    let (mut soff, mut roff) = (0usize, 0usize);
    for j in 0..p {
        scounts.push(scount(j));
        rcounts.push(rcount(j));
        sdispls.push(soff);
        rdispls.push(roff);
        if opts.use_even {
            soff += even_block;
            roff += even_block;
        } else {
            soff += scount(j);
            roff += rcount(j);
        }
    }
    (scounts, sdispls, rcounts, rdispls)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::grid::ProcGrid;
    use crate::mpi::Universe;

    fn enc(x: usize, y: usize, z: usize) -> Complex<f64> {
        Complex::new((x * 1_000_000 + y * 1_000 + z) as f64, -1.0)
    }

    /// Distributed X→Y→Z forward chain on encoded global coordinates, then
    /// back — every element must land at its Table-1 location and return.
    fn roundtrip_case(nx: usize, ny: usize, nz: usize, m1: usize, m2: usize, use_even: bool) {
        let decomp = Decomp::new(nx, ny, nz, ProcGrid::new(m1, m2)).unwrap();
        let opts = ExchangeOptions { use_even, ..Default::default() };
        let u = Universe::new(decomp.p());
        let results = u
            .run(move |c| {
                let rank = c.rank();
                let (row, col) = c.cart_2d(decomp.pgrid)?;
                let txy = TransposeXY::new(&decomp, rank);
                let tyz = TransposeYZ::new(&decomp, rank);
                let xp = decomp.x_pencil_spec(rank);
                let yp = decomp.y_pencil(rank);
                let zp = decomp.z_pencil(rank);
                let mut timer = StageTimer::new();

                // Fill the spectral X-pencil with encoded global coords.
                let mut xdata = vec![Complex::zero(); xp.len()];
                for z in 0..xp.dims[0] {
                    for y in 0..xp.dims[1] {
                        for x in 0..decomp.h() {
                            xdata[(z * xp.dims[1] + y) * decomp.h() + x] =
                                enc(x, y + xp.offsets[1], z + xp.offsets[0]);
                        }
                    }
                }

                let blen = txy.buf_len(opts).max(tyz.buf_len(opts));
                let mut sb = vec![Complex::zero(); blen];
                let mut rb = vec![Complex::zero(); blen];

                let mut ydata = vec![Complex::zero(); yp.len()];
                txy.forward(&row, &xdata, &mut ydata, &mut sb, &mut rb, opts, &mut timer);
                // Verify Y-pencil contents.
                for z in 0..yp.dims[0] {
                    for xl in 0..yp.dims[1] {
                        for y in 0..decomp.ny {
                            let got = ydata[(z * yp.dims[1] + xl) * decomp.ny + y];
                            let want = enc(xl + yp.offsets[1], y, z + yp.offsets[0]);
                            if got != want {
                                return Err(crate::Error::Mpi(format!(
                                    "rank {rank} ypencil mismatch at z={z} x={xl} y={y}: {got} != {want}"
                                )));
                            }
                        }
                    }
                }

                let mut zdata = vec![Complex::zero(); zp.len()];
                tyz.forward(&col, &ydata, &mut zdata, &mut sb, &mut rb, opts, &mut timer);
                for xl in 0..zp.dims[0] {
                    for yl in 0..zp.dims[1] {
                        for z in 0..decomp.nz {
                            let got = zdata[(xl * zp.dims[1] + yl) * decomp.nz + z];
                            let want = enc(xl + zp.offsets[0], yl + zp.offsets[1], z);
                            if got != want {
                                return Err(crate::Error::Mpi(format!(
                                    "rank {rank} zpencil mismatch: {got} != {want}"
                                )));
                            }
                        }
                    }
                }

                // And back.
                let mut yback = vec![Complex::zero(); yp.len()];
                tyz.backward(&col, &zdata, &mut yback, &mut sb, &mut rb, opts, &mut timer);
                if yback != ydata {
                    return Err(crate::Error::Mpi(format!("rank {rank} Z->Y backward mismatch")));
                }
                let mut xback = vec![Complex::zero(); xp.len()];
                txy.backward(&row, &yback, &mut xback, &mut sb, &mut rb, opts, &mut timer);
                if xback != xdata {
                    return Err(crate::Error::Mpi(format!("rank {rank} Y->X backward mismatch")));
                }
                Ok(true)
            })
            .unwrap();
        assert!(results.into_iter().all(|b| b));
    }

    #[test]
    fn even_grid_2x2() {
        roundtrip_case(8, 8, 8, 2, 2, false);
    }

    #[test]
    fn even_grid_2x2_useeven() {
        roundtrip_case(8, 8, 8, 2, 2, true);
    }

    #[test]
    fn uneven_grid_3x2() {
        roundtrip_case(10, 9, 7, 3, 2, false);
    }

    #[test]
    fn uneven_grid_3x2_useeven() {
        roundtrip_case(10, 9, 7, 3, 2, true);
    }

    #[test]
    fn one_d_decomposition_1xp() {
        // 1D slab decomposition: ROW is trivial (M1=1), all exchange in
        // the COLUMN transpose.
        roundtrip_case(8, 8, 8, 1, 4, false);
    }

    #[test]
    fn one_d_decomposition_px1() {
        roundtrip_case(8, 12, 8, 4, 1, false);
    }

    #[test]
    fn tall_processor_grid() {
        roundtrip_case(16, 12, 10, 2, 5, false);
    }

    /// Full transpose chain under both copy disciplines on flat and
    /// 2-node fabrics: every pencil byte must match the mailbox baseline
    /// (USEEVEN leg included — windows carry true counts there).
    fn copy_mode_case(use_even: bool) {
        use crate::mpi::{Hierarchy, PlacementPolicy};
        let decomp = Decomp::new(10, 9, 7, ProcGrid::new(2, 2)).unwrap();
        let run = |copy: CopyMode, topo: Hierarchy| {
            let decomp = decomp.clone();
            let u = Universe::with_topology(decomp.p(), topo);
            u.run(move |c| {
                let rank = c.rank();
                let opts = ExchangeOptions { use_even, copy };
                let (row, col) = c.cart_2d(decomp.pgrid)?;
                let txy = TransposeXY::new(&decomp, rank);
                let tyz = TransposeYZ::new(&decomp, rank);
                let xp = decomp.x_pencil_spec(rank);
                let yp = decomp.y_pencil(rank);
                let zp = decomp.z_pencil(rank);
                let mut timer = StageTimer::new();
                let mut xdata = vec![Complex::zero(); xp.len()];
                for z in 0..xp.dims[0] {
                    for y in 0..xp.dims[1] {
                        for x in 0..decomp.h() {
                            xdata[(z * xp.dims[1] + y) * decomp.h() + x] =
                                enc(x, y + xp.offsets[1], z + xp.offsets[0]);
                        }
                    }
                }
                let blen = txy.buf_len(opts).max(tyz.buf_len(opts));
                let mut sb = vec![Complex::zero(); blen];
                let mut rb = vec![Complex::zero(); blen];
                let mut ydata = vec![Complex::zero(); yp.len()];
                txy.forward(&row, &xdata, &mut ydata, &mut sb, &mut rb, opts, &mut timer);
                let mut zdata = vec![Complex::zero(); zp.len()];
                tyz.forward(&col, &ydata, &mut zdata, &mut sb, &mut rb, opts, &mut timer);
                let mut yback = vec![Complex::zero(); yp.len()];
                tyz.backward(&col, &zdata, &mut yback, &mut sb, &mut rb, opts, &mut timer);
                let mut xback = vec![Complex::zero(); xp.len()];
                txy.backward(&row, &yback, &mut xback, &mut sb, &mut rb, opts, &mut timer);
                Ok((ydata, zdata, yback, xback))
            })
            .unwrap()
        };
        let base = run(CopyMode::Mailbox, Hierarchy::flat(4));
        for topo in [
            Hierarchy::flat(4),
            Hierarchy::two_level(4, 2, PlacementPolicy::Contiguous),
            Hierarchy::two_level(4, 2, PlacementPolicy::RoundRobin),
        ] {
            assert_eq!(run(CopyMode::SingleCopy, topo), base);
        }
    }

    #[test]
    fn single_copy_matches_mailbox_bit_for_bit() {
        copy_mode_case(false);
    }

    #[test]
    fn single_copy_matches_mailbox_bit_for_bit_useeven() {
        copy_mode_case(true);
    }

    #[test]
    fn single_copy_xyz_receives_in_place_with_empty_recvbuf() {
        // The XYZ Y→Z forward lands straight in the Z-pencil on the
        // single-copy path; the scratch recv buffer may be empty. Payload
        // must match the mailbox path with a real recv buffer.
        let decomp = Decomp::new(8, 9, 10, ProcGrid::new(1, 4)).unwrap();
        let run = |copy: CopyMode| {
            let decomp = decomp.clone();
            let u = Universe::new(decomp.p());
            u.run(move |c| {
                let rank = c.rank();
                let opts = ExchangeOptions { use_even: false, copy };
                let (_row, col) = c.cart_2d(decomp.pgrid)?;
                let tyz = TransposeYZ::new(&decomp, rank);
                let yp = decomp.y_pencil(rank);
                let mut timer = StageTimer::new();
                // XYZ-order Y-pencil [nz_loc][ny_glob][h_loc].
                let (nzl, hl, ny) = (tyz.nz_loc(), tyz.h_loc, tyz.ny_glob);
                let mut ydata = vec![Complex::zero(); nzl * ny * hl];
                for z in 0..nzl {
                    for y in 0..ny {
                        for x in 0..hl {
                            ydata[(z * ny + y) * hl + x] =
                                enc(x, y, z + yp.offsets[0]);
                        }
                    }
                }
                let blen = tyz.buf_len(opts);
                let mut sb = vec![Complex::zero(); blen];
                let mut rb = match copy {
                    CopyMode::SingleCopy => Vec::new(),
                    CopyMode::Mailbox => vec![Complex::zero(); blen],
                };
                let mut zdata = vec![Complex::zero(); tyz.nz_glob * tyz.ny2_loc() * hl];
                tyz.forward_xyz(&col, &ydata, &mut zdata, &mut sb, &mut rb, opts, &mut timer);
                Ok(zdata)
            })
            .unwrap()
        };
        assert_eq!(run(CopyMode::SingleCopy), run(CopyMode::Mailbox));
    }

    #[test]
    fn chunk_plans_partition_the_full_exchange() {
        // Sum of per-chunk counts must equal the blocking counts, chunk
        // windows must be disjoint, and everything must fit in buf_len —
        // for uneven grids and k not dividing the axis.
        let decomp = Decomp::new(10, 9, 7, ProcGrid::new(3, 2)).unwrap();
        let opts = ExchangeOptions { use_even: false, ..Default::default() };
        for rank in 0..decomp.p() {
            let txy = TransposeXY::new(&decomp, rank);
            let tyz = TransposeYZ::new(&decomp, rank);
            for k in [1usize, 2, 3, 7, 16] {
                let cp = txy.chunks_fwd(k);
                assert!(cp.len() <= k.max(1) && !cp.is_empty());
                for j in 0..txy.m1 {
                    let total: usize = cp.chunks.iter().map(|c| c.scounts[j]).sum();
                    assert_eq!(total, txy.scount_fwd(j), "rank {rank} k {k} peer {j}");
                    let rtotal: usize = cp.chunks.iter().map(|c| c.rcounts[j]).sum();
                    assert_eq!(rtotal, txy.rcount_fwd(j));
                }
                // Ranges partition the invariant axis in order.
                let mut pos = 0;
                for c in &cp.chunks {
                    assert_eq!(c.range.start, pos);
                    assert!(!c.range.is_empty());
                    pos = c.range.end;
                }
                assert_eq!(pos, txy.nz);
                // Displacement windows stay inside the blocking buffers.
                for c in &cp.chunks {
                    for j in 0..txy.m1 {
                        assert!(c.sdispls[j] + c.scounts[j] <= txy.buf_len(opts));
                        assert!(c.rdispls[j] + c.rcounts[j] <= txy.buf_len(opts));
                    }
                }

                let cpz = tyz.chunks_fwd(k);
                for j in 0..tyz.m2 {
                    let total: usize = cpz.chunks.iter().map(|c| c.scounts[j]).sum();
                    assert_eq!(total, tyz.scount_fwd(j));
                    let rtotal: usize = cpz.chunks.iter().map(|c| c.rcounts[j]).sum();
                    assert_eq!(rtotal, tyz.rcount_fwd(j));
                }
                // Backward views swap the roles exactly.
                let cb = txy.chunks_bwd(k);
                for (f, b) in cp.chunks.iter().zip(&cb.chunks) {
                    assert_eq!(f.range, b.range);
                    assert_eq!(f.scounts, b.rcounts);
                    assert_eq!(f.rcounts, b.scounts);
                }
            }
        }
    }

    #[test]
    fn two_level_topology_roundtrip_matches_flat_bit_for_bit() {
        // The same distributed transpose chain on a flat fabric and on a
        // two-node fabric (intra-node-first peer ordering, modeled link
        // accounting) must produce identical pencils at every step —
        // roundtrip_case verifies exact equality against the encoded
        // coordinates internally, so running it under both topologies
        // pins the schedule-invariance of the exchange.
        let decomp = Decomp::new(10, 9, 7, ProcGrid::new(3, 2)).unwrap();
        let opts = ExchangeOptions { use_even: false, ..Default::default() };
        let run = |u: Universe| {
            u.run(move |c| {
                let rank = c.rank();
                let (row, col) = c.cart_2d(decomp.pgrid)?;
                let txy = TransposeXY::new(&decomp, rank);
                let tyz = TransposeYZ::new(&decomp, rank);
                let xp = decomp.x_pencil_spec(rank);
                let yp = decomp.y_pencil(rank);
                let zp = decomp.z_pencil(rank);
                let mut timer = StageTimer::new();
                let mut xdata = vec![Complex::zero(); xp.len()];
                for z in 0..xp.dims[0] {
                    for y in 0..xp.dims[1] {
                        for x in 0..decomp.h() {
                            xdata[(z * xp.dims[1] + y) * decomp.h() + x] =
                                enc(x, y + xp.offsets[1], z + xp.offsets[0]);
                        }
                    }
                }
                let blen = txy.buf_len(opts).max(tyz.buf_len(opts));
                let mut sb = vec![Complex::zero(); blen];
                let mut rb = vec![Complex::zero(); blen];
                let mut ydata = vec![Complex::zero(); yp.len()];
                txy.forward(&row, &xdata, &mut ydata, &mut sb, &mut rb, opts, &mut timer);
                let mut zdata = vec![Complex::zero(); zp.len()];
                tyz.forward(&col, &ydata, &mut zdata, &mut sb, &mut rb, opts, &mut timer);
                Ok(zdata)
            })
            .unwrap()
        };
        let flat = run(Universe::new(decomp.p()));
        let two_level = run(Universe::with_topology(
            decomp.p(),
            crate::mpi::Hierarchy::two_level(
                decomp.p(),
                3,
                crate::mpi::PlacementPolicy::Contiguous,
            ),
        ));
        assert_eq!(flat, two_level, "node map must never change the payload");
    }

    #[test]
    fn useeven_padding_matches_alltoallv_results() {
        // Same decomposition both ways must produce identical pencils —
        // padding must never leak into the data.
        roundtrip_case(12, 10, 9, 3, 3, true);
        roundtrip_case(12, 10, 9, 3, 3, false);
    }

    use crate::grid::truncation::Truncation;

    fn pruned_pair(decomp: &Decomp, rule: &PruneRule, rank: usize) -> (TransposeXY, TransposeYZ) {
        let txy = TransposeXY::new(decomp, rank).with_kx_keep(rule.kx_keep());
        let yp = decomp.y_pencil(rank);
        let tyz = TransposeYZ::new(decomp, rank).with_prune(rule, yp.offsets[1]);
        (txy, tyz)
    }

    #[test]
    fn pruned_counts_are_symmetric_and_sum_to_retained_totals() {
        let decomp = Decomp::new(10, 12, 14, ProcGrid::new(2, 3)).unwrap();
        let rule = PruneRule::new([10, 12, 14], Truncation::Spherical23);
        let plans: Vec<_> = (0..decomp.p()).map(|r| pruned_pair(&decomp, &rule, r)).collect();

        // Cross-rank symmetry: what i sends to j, j expects from i.
        for a in 0..decomp.p() {
            for b in 0..decomp.p() {
                let (ra1, ra2) = decomp.pgrid.coords(a);
                let (rb1, rb2) = decomp.pgrid.coords(b);
                if ra2 == rb2 {
                    assert_eq!(
                        plans[a].0.scount_fwd(rb1),
                        plans[b].0.rcount_fwd(ra1),
                        "XY {a}->{b}"
                    );
                }
                if ra1 == rb1 {
                    assert_eq!(
                        plans[a].1.scount_fwd(rb2),
                        plans[b].1.rcount_fwd(ra2),
                        "YZ {a}->{b}"
                    );
                }
            }
        }

        // Grid-wide Y→Z send volume == retained pairs × nz (columns
        // partition the x axis; each column's ranks tile nz).
        let total: usize = plans
            .iter()
            .map(|(_, tyz)| (0..tyz.m2).map(|j| tyz.scount_fwd(j)).sum::<usize>())
            .sum();
        assert_eq!(total, rule.retained_pairs() * 14);
        // Recv side agrees.
        let rtotal: usize = plans
            .iter()
            .map(|(_, tyz)| (0..tyz.m2).map(|j| tyz.rcount_fwd(j)).sum::<usize>())
            .sum();
        assert_eq!(rtotal, total);
    }

    #[test]
    fn pruned_chunk_plans_partition_the_pruned_exchange() {
        // Pruned Y↔Z planes are non-uniform (each x row keeps a
        // different pair count) — chunk sums must still reproduce the
        // blocking counts exactly, for every chunking.
        let decomp = Decomp::new(10, 12, 14, ProcGrid::new(2, 3)).unwrap();
        let rule = PruneRule::new([10, 12, 14], Truncation::Spherical23);
        let opts = ExchangeOptions { use_even: false, ..Default::default() };
        fn check(
            cp: &ChunkPlan,
            m: usize,
            sc: impl Fn(usize) -> usize,
            rc: impl Fn(usize) -> usize,
            buf: usize,
            tag: &str,
        ) {
            for j in 0..m {
                let s: usize = cp.chunks.iter().map(|c| c.scounts[j]).sum();
                assert_eq!(s, sc(j), "{tag} peer {j}");
                let r: usize = cp.chunks.iter().map(|c| c.rcounts[j]).sum();
                assert_eq!(r, rc(j), "{tag} peer {j}");
            }
            for c in &cp.chunks {
                for j in 0..m {
                    assert!(c.sdispls[j] + c.scounts[j] <= buf, "{tag}");
                    assert!(c.rdispls[j] + c.rcounts[j] <= buf, "{tag}");
                }
            }
        }
        for rank in 0..decomp.p() {
            let (txy, tyz) = pruned_pair(&decomp, &rule, rank);
            for k in [1usize, 2, 3, 7, 16] {
                let tag = format!("rank {rank} k {k}");
                check(
                    &txy.chunks_fwd(k),
                    txy.m1,
                    |j| txy.scount_fwd(j),
                    |j| txy.rcount_fwd(j),
                    txy.buf_len(opts),
                    &format!("XY {tag}"),
                );
                check(
                    &tyz.chunks_fwd(k),
                    tyz.m2,
                    |j| tyz.scount_fwd(j),
                    |j| tyz.rcount_fwd(j),
                    tyz.buf_len(opts),
                    &format!("YZ {tag}"),
                );
                // Backward views swap roles exactly.
                let (f, b) = (tyz.chunks_fwd(k), tyz.chunks_bwd(k));
                for (fc, bc) in f.chunks.iter().zip(&b.chunks) {
                    assert_eq!(fc.range, bc.range);
                    assert_eq!(fc.scounts, bc.rcounts);
                    assert_eq!(fc.rcounts, bc.scounts);
                }
            }
        }
    }

    #[test]
    fn pruned_exchange_matches_full_on_retained_modes() {
        // Distributed X→Y→Z with truncation: retained modes must equal
        // the full-grid transpose chain bit for bit, pruned slots must
        // be exact zeros, and the backward chain must restore the
        // retained modes (zero elsewhere).
        let decomp = Decomp::new(10, 12, 14, ProcGrid::new(2, 3)).unwrap();
        let rule = PruneRule::new([10, 12, 14], Truncation::Spherical23);
        let opts = ExchangeOptions { use_even: false, ..Default::default() };
        let u = Universe::new(decomp.p());
        let checks = u
            .run(move |c| {
                let rank = c.rank();
                let (row, col) = c.cart_2d(decomp.pgrid)?;
                let txy = TransposeXY::new(&decomp, rank);
                let tyz = TransposeYZ::new(&decomp, rank);
                let (pxy, pyz) = pruned_pair(&decomp, &rule, rank);
                let xp = decomp.x_pencil_spec(rank);
                let yp = decomp.y_pencil(rank);
                let zp = decomp.z_pencil(rank);
                let mut timer = StageTimer::new();

                let mut xdata = vec![Complex::zero(); xp.len()];
                for z in 0..xp.dims[0] {
                    for y in 0..xp.dims[1] {
                        for x in 0..decomp.h() {
                            xdata[(z * xp.dims[1] + y) * decomp.h() + x] =
                                enc(x, y + xp.offsets[1], z + xp.offsets[0]);
                        }
                    }
                }

                let blen = txy.buf_len(opts).max(tyz.buf_len(opts));
                let mut sb = vec![Complex::zero(); blen];
                let mut rb = vec![Complex::zero(); blen];

                // Full-grid reference chain.
                let mut yref = vec![Complex::zero(); yp.len()];
                txy.forward(&row, &xdata, &mut yref, &mut sb, &mut rb, opts, &mut timer);
                let mut zref = vec![Complex::zero(); zp.len()];
                tyz.forward(&col, &yref, &mut zref, &mut sb, &mut rb, opts, &mut timer);

                // Pruned chain (smaller wire volume, same buffers).
                let mut ydata = vec![Complex::zero(); yp.len()];
                pxy.forward(&row, &xdata, &mut ydata, &mut sb, &mut rb, opts, &mut timer);
                let mut zdata = vec![Complex::zero(); zp.len()];
                pyz.forward(&col, &ydata, &mut zdata, &mut sb, &mut rb, opts, &mut timer);

                let pr = pyz.prune.as_ref().unwrap();
                let ny2 = zp.dims[1];
                for xl in 0..zp.dims[0] {
                    for yl in 0..ny2 {
                        let kept = pr.keep_own[xl * ny2 + yl];
                        for z in 0..decomp.nz {
                            let got = zdata[(xl * ny2 + yl) * decomp.nz + z];
                            let want =
                                if kept { zref[(xl * ny2 + yl) * decomp.nz + z] } else { Complex::zero() };
                            if got != want {
                                return Err(crate::Error::Mpi(format!(
                                    "rank {rank} pruned zpencil mismatch at x={xl} y={yl} z={z} (kept={kept}): {got} != {want}"
                                )));
                            }
                        }
                    }
                }

                // Backward: retained modes return, everything else zero.
                let mut yback = vec![Complex::zero(); yp.len()];
                pyz.backward(&col, &zdata, &mut yback, &mut sb, &mut rb, opts, &mut timer);
                for z in 0..yp.dims[0] {
                    for xl in 0..yp.dims[1] {
                        for y in 0..decomp.ny {
                            let got = yback[(z * yp.dims[1] + xl) * decomp.ny + y];
                            let kept = pr.keep[xl * decomp.ny + y];
                            let want = if kept {
                                yref[(z * yp.dims[1] + xl) * decomp.ny + y]
                            } else {
                                Complex::zero()
                            };
                            if got != want {
                                return Err(crate::Error::Mpi(format!(
                                    "rank {rank} pruned yback mismatch at z={z} x={xl} y={y}: {got} != {want}"
                                )));
                            }
                        }
                    }
                }

                let mut xback = vec![Complex::zero(); xp.len()];
                pxy.backward(&row, &yback, &mut xback, &mut sb, &mut rb, opts, &mut timer);
                for z in 0..xp.dims[0] {
                    for y in 0..xp.dims[1] {
                        for x in 0..decomp.h() {
                            let got = xback[(z * xp.dims[1] + y) * decomp.h() + x];
                            let kept = rule.keep_pair(x, y + xp.offsets[1]);
                            let want = if kept {
                                xdata[(z * xp.dims[1] + y) * decomp.h() + x]
                            } else {
                                Complex::zero()
                            };
                            if got != want {
                                return Err(crate::Error::Mpi(format!(
                                    "rank {rank} pruned xback mismatch at z={z} y={y} x={x}: {got} != {want}"
                                )));
                            }
                        }
                    }
                }
                Ok(true)
            })
            .unwrap();
        assert!(checks.into_iter().all(|b| b));
    }

    #[test]
    fn pruned_useeven_matches_pruned_alltoallv() {
        // USEEVEN padding composes with pruning: both transports must
        // land identical Z-pencils.
        let decomp = Decomp::new(12, 12, 12, ProcGrid::new(2, 2)).unwrap();
        let rule = PruneRule::new([12, 12, 12], Truncation::Spherical23);
        let run = |use_even: bool| {
            let opts = ExchangeOptions { use_even, ..Default::default() };
            let u = Universe::new(decomp.p());
            u.run(move |c| {
                let rank = c.rank();
                let (row, col) = c.cart_2d(decomp.pgrid)?;
                let (pxy, pyz) = pruned_pair(&decomp, &rule, rank);
                let xp = decomp.x_pencil_spec(rank);
                let yp = decomp.y_pencil(rank);
                let zp = decomp.z_pencil(rank);
                let mut timer = StageTimer::new();
                let mut xdata = vec![Complex::zero(); xp.len()];
                for z in 0..xp.dims[0] {
                    for y in 0..xp.dims[1] {
                        for x in 0..decomp.h() {
                            xdata[(z * xp.dims[1] + y) * decomp.h() + x] =
                                enc(x, y + xp.offsets[1], z + xp.offsets[0]);
                        }
                    }
                }
                let blen = pxy.buf_len(opts).max(pyz.buf_len(opts));
                let mut sb = vec![Complex::zero(); blen];
                let mut rb = vec![Complex::zero(); blen];
                let mut ydata = vec![Complex::zero(); yp.len()];
                pxy.forward(&row, &xdata, &mut ydata, &mut sb, &mut rb, opts, &mut timer);
                let mut zdata = vec![Complex::zero(); zp.len()];
                pyz.forward(&col, &ydata, &mut zdata, &mut sb, &mut rb, opts, &mut timer);
                Ok(zdata)
            })
            .unwrap()
        };
        assert_eq!(run(true), run(false), "padding must never leak into pruned data");
    }
}
