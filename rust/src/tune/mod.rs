//! Plan-time autotuner — the paper's stated goal of a framework that
//! "helps guide the user in making optimal choices for parameters of
//! their runs", made executable (cf. OpenFFT's plan-time decomposition
//! selection and AccFFT's automatic comm-strategy choice).
//!
//! Pipeline: **probe → score → refine.**
//!
//! 1. *Probe* ([`profile`]) — a machine profile supplies the Eq.-3
//!    constants: either a fixed synthetic machine (paper presets, nominal
//!    host) or constants calibrated from in-process micro-probes of the
//!    library's own pack/FFT/alltoall kernels (the `calib_*` benches at
//!    reduced size).
//! 2. *Score* ([`candidates`], [`score`]) — enumerate every Eq.-2-feasible
//!    `(m1, m2)` factorization of P crossed with `use_even` and
//!    `overlap_chunks` settings, and price each with
//!    [`crate::netmodel::predict_overlapped`] (the Fig.-3 aspect-ratio
//!    effects, the §3.4 Alltoallv penalty and the chunked-overlap optimum
//!    all fall out of the model).
//! 3. *Refine* ([`refine`], optional) — re-measure the top-K candidates
//!    with short real pipeline runs on thread ranks and let wall-clock
//!    numbers settle the final order.
//!
//! Entry points: [`autotune`] (returns a ranked [`TuneReport`]),
//! [`crate::coordinator::PlanSpec::autotune`] (report + concrete spec),
//! `grid.pgrid = "auto"` / `options.overlap_chunks = "auto"` in run
//! configs, and the `p3dfft tune` CLI subcommand.

pub mod candidates;
pub mod profile;
pub mod refine;
pub mod report;
pub mod score;

pub use candidates::{
    chunk_candidates, enumerate, grid_candidates, max_executable_chunks, Candidate,
};
pub use profile::{MachineProfile, ProfileSource};
pub use report::{TuneEntry, TuneReport};

use crate::util::error::{Error, Result};

/// Tuner knobs. `Default` is the deterministic model-only path on the
/// nominal host profile (no timing anywhere — same inputs, same ranking).
#[derive(Debug, Clone)]
pub struct TuneOptions {
    /// Machine profile candidates are priced on.
    pub profile: MachineProfile,
    /// Bytes per exchanged element (16 = complex f64, 8 = complex f32).
    pub elem_bytes: f64,
    /// Explore `use_even` (both settings) or pin it to `false`.
    /// Ignored when `pin_use_even` is set.
    pub explore_use_even: bool,
    /// Explore `overlap_chunks > 1` or pin the blocking pipeline.
    /// Ignored when `pin_overlap_chunks` is set.
    pub explore_overlap: bool,
    /// Price every candidate with exactly this `use_even` — the value the
    /// run will actually use (the USEEVEN padding cost depends on the
    /// grid, so tuning under a different setting optimises the wrong
    /// objective). `None` falls back to `explore_use_even`.
    pub pin_use_even: Option<bool>,
    /// Price every candidate with exactly this `overlap_chunks`;
    /// `None` falls back to `explore_overlap`.
    pub pin_overlap_chunks: Option<usize>,
    /// Topology-aware placement scoring: group the `nprocs` ranks into
    /// contiguous nodes of this many cores and price every candidate with
    /// the two-level, intra-node-first schedule model
    /// ([`crate::netmodel::predict_two_level`]), recording each grid's
    /// ROW/COLUMN intra-node fractions in the report. Rows are contiguous
    /// rank blocks of `m1`, so the winner keeps ROW sub-communicators
    /// on-node whenever a feasible `m1 <= cores_per_node` grid exists.
    /// `None` (default) keeps the exact legacy single-level scoring.
    pub cores_per_node: Option<usize>,
    /// Price candidates for a *truncated* (pruned) run: the exchanges
    /// ship only retained modes, so each wire term is scaled by its
    /// retained fraction ([`crate::grid::PruneRule`]) before pipelining.
    /// This lets the tuner score `(m1, m2)` × truncation jointly — a
    /// pruned Y→Z exchange shifts the aspect-ratio optimum toward taller
    /// grids. `None` (default) prices the full-grid transform and is
    /// bit-identical to the pre-truncation tuner.
    pub truncation: Option<crate::grid::Truncation>,
    /// Exchange copy discipline the run will use. Only the two-level
    /// (`cores_per_node`) scoring prices it: single-copy windows halve
    /// the memory streams of each intra-node block, so on-node placement
    /// pays off even more than under the mailbox. Defaults to the
    /// runtime's own default (single-copy) without consulting the
    /// environment, keeping model-only tuning deterministic.
    pub copy: crate::mpi::CopyMode,
    /// Refine this many of the model's top candidates with short real
    /// pipeline runs (0 = model-only, fully deterministic).
    pub refine_top_k: usize,
    /// Forward+backward pairs measured per refined candidate.
    pub refine_iters: usize,
    /// PRNG seed for the refinement workload (recorded in the report).
    pub seed: u64,
}

impl Default for TuneOptions {
    fn default() -> Self {
        TuneOptions {
            profile: MachineProfile::nominal_host(),
            elem_bytes: 16.0,
            explore_use_even: true,
            explore_overlap: true,
            pin_use_even: None,
            pin_overlap_chunks: None,
            cores_per_node: None,
            truncation: None,
            copy: crate::mpi::CopyMode::SingleCopy,
            refine_top_k: 0,
            refine_iters: 1,
            seed: 0x5EED_CAFE,
        }
    }
}

/// Rank every feasible candidate for `dims` on `nprocs` ranks.
///
/// Deterministic for a synthetic profile with `refine_top_k == 0`; the
/// sort order is total (score, then m1, use_even, chunks), so ties —
/// e.g. `1xP` vs `Px1` on a symmetric machine — break toward the smaller
/// `m1` (fewer ROW exchanges is the Fig.-10 preference) and the simpler
/// option settings.
pub fn autotune(dims: [usize; 3], nprocs: usize, opts: &TuneOptions) -> Result<TuneReport> {
    if nprocs == 0 {
        return Err(Error::InvalidConfig("autotune needs nprocs >= 1".into()));
    }
    let evens: Vec<bool> = match opts.pin_use_even {
        Some(v) => vec![v],
        None if opts.explore_use_even => vec![false, true],
        None => vec![false],
    };
    let chunks: Vec<usize> = match opts.pin_overlap_chunks {
        Some(0) => {
            // Same contract as PlanSpec::with_overlap_chunks — no silent
            // clamping of an invalid chunk count.
            return Err(Error::InvalidConfig(
                "options.overlap_chunks must be >= 1, got 0".into(),
            ));
        }
        Some(k) => vec![k],
        None if opts.explore_overlap => candidates::chunk_candidates(dims),
        None => vec![1],
    };
    let cands = candidates::enumerate(dims, nprocs, &evens, &chunks);
    if cands.is_empty() {
        return Err(Error::InvalidConfig(format!(
            "no Eq.-2-feasible processor grid: {}x{}x{} cannot be decomposed over {} ranks",
            dims[0], dims[1], dims[2], nprocs
        )));
    }
    let nodes = opts.cores_per_node.map(|c| {
        crate::mpi::NodeMap::new(nprocs, c.max(1), crate::mpi::PlacementPolicy::Contiguous)
    });
    // (1.0, 1.0) for a full-grid run, so the untruncated ranking is
    // bit-identical to the pre-truncation tuner.
    let keep = score::keep_fractions(dims, opts.truncation);
    let mut entries: Vec<TuneEntry> = cands
        .into_iter()
        .map(|cand| match &nodes {
            Some(nm) => {
                let t = score::model_seconds_pruned_two_level(
                    dims,
                    &cand,
                    &opts.profile,
                    opts.elem_bytes,
                    nm,
                    keep,
                    opts.copy,
                );
                TuneEntry {
                    cand,
                    model_s: t.aware_s,
                    measured_s: None,
                    row_intra: Some(t.row_intra),
                    col_intra: Some(t.col_intra),
                }
            }
            None => TuneEntry {
                cand,
                model_s: score::model_seconds_pruned(
                    dims,
                    &cand,
                    &opts.profile,
                    opts.elem_bytes,
                    keep,
                ),
                measured_s: None,
                row_intra: None,
                col_intra: None,
            },
        })
        .collect();
    entries.sort_by(|a, b| {
        a.model_s
            .partial_cmp(&b.model_s)
            .unwrap_or(std::cmp::Ordering::Equal)
            .then(a.cand.m1.cmp(&b.cand.m1))
            .then(a.cand.use_even.cmp(&b.cand.use_even))
            .then(a.cand.overlap_chunks.cmp(&b.cand.overlap_chunks))
    });

    if opts.refine_top_k > 0 {
        let k = opts.refine_top_k.min(entries.len());
        for e in entries.iter_mut().take(k) {
            e.measured_s = Some(refine::measure_candidate(
                dims,
                &e.cand,
                opts.refine_iters,
                opts.seed,
            )?);
        }
        // Refined candidates rank ahead, by measured pair time; the rest
        // keep their model order behind them.
        entries.sort_by(|a, b| match (a.measured_s, b.measured_s) {
            (Some(x), Some(y)) => x.partial_cmp(&y).unwrap_or(std::cmp::Ordering::Equal),
            (Some(_), None) => std::cmp::Ordering::Less,
            (None, Some(_)) => std::cmp::Ordering::Greater,
            (None, None) => a.model_s.partial_cmp(&b.model_s).unwrap_or(std::cmp::Ordering::Equal),
        });
    }

    Ok(TuneReport {
        dims,
        nprocs,
        profile: opts.profile.name.clone(),
        seed: opts.seed,
        entries,
    })
}

/// Best `overlap_chunks` for an already-chosen grid, by model (used when
/// `options.overlap_chunks = "auto"` rides on an explicit `grid.pgrid`).
pub fn best_chunks(
    dims: [usize; 3],
    m1: usize,
    m2: usize,
    use_even: bool,
    profile: &MachineProfile,
    elem_bytes: f64,
) -> usize {
    let cap = candidates::max_executable_chunks(dims, m1, m2);
    let mut ladder: Vec<usize> =
        chunk_candidates(dims).into_iter().map(|k| k.min(cap)).collect();
    ladder.dedup(); // ascending ladder stays sorted after the clamp
    ladder
        .into_iter()
        .min_by(|&a, &b| {
            let ta = score::model_seconds(
                dims,
                &Candidate { m1, m2, use_even, overlap_chunks: a },
                profile,
                elem_bytes,
            );
            let tb = score::model_seconds(
                dims,
                &Candidate { m1, m2, use_even, overlap_chunks: b },
                profile,
                elem_bytes,
            );
            ta.partial_cmp(&tb).unwrap_or(std::cmp::Ordering::Equal).then(a.cmp(&b))
        })
        .unwrap_or(1)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::netmodel::Machine;

    #[test]
    fn model_only_ranking_is_deterministic() {
        let opts = TuneOptions::default();
        let a = autotune([64, 64, 64], 8, &opts).unwrap();
        let b = autotune([64, 64, 64], 8, &opts).unwrap();
        assert_eq!(a.entries.len(), b.entries.len());
        for (x, y) in a.entries.iter().zip(&b.entries) {
            assert_eq!(x.cand, y.cand);
            assert_eq!(x.model_s, y.model_s);
        }
    }

    #[test]
    fn infeasible_problem_is_an_error() {
        // 4x4x4: h = 3, m1 <= 3 and m2 <= 4; P = 64 has no feasible pair
        // (minimum P for any factorization would need m1*m2=64 with m1<=3,
        // m2<=4 -> max 12 < 64).
        assert!(autotune([4, 4, 4], 64, &TuneOptions::default()).is_err());
        assert!(autotune([64, 64, 64], 0, &TuneOptions::default()).is_err());
    }

    #[test]
    fn pinned_zero_chunks_is_invalid_config() {
        let opts =
            TuneOptions { pin_overlap_chunks: Some(0), ..TuneOptions::default() };
        let err = autotune([64, 64, 64], 8, &opts).unwrap_err();
        assert!(err.to_string().contains("overlap_chunks"), "{err}");
    }

    #[test]
    fn cray_profile_prefers_on_node_rows() {
        // Fig. 3: on the XT5 the winner keeps M1 <= cores/node.
        let opts = TuneOptions {
            profile: MachineProfile::synthetic(Machine::cray_xt5()),
            explore_use_even: false,
            explore_overlap: false,
            ..TuneOptions::default()
        };
        let r = autotune([2048, 2048, 2048], 1024, &opts).unwrap();
        let best = &r.best().cand;
        assert!(
            best.m1 <= 12,
            "winner {}x{} should keep rows on a 12-core node",
            best.m1,
            best.m2
        );
    }

    #[test]
    fn topology_scoring_keeps_rows_on_node_and_reports_placement() {
        // 16 ranks on 4-core nodes: grids with m1 <= 4 keep every ROW
        // sub-communicator inside one node, and the winner must be one of
        // them (the two-level model prices cross-node rows at the slow
        // inter-node bisection).
        let opts = TuneOptions {
            profile: MachineProfile::synthetic(Machine::ranger()),
            cores_per_node: Some(4),
            explore_use_even: false,
            explore_overlap: false,
            ..TuneOptions::default()
        };
        let r = autotune([256, 256, 256], 16, &opts).unwrap();
        let best = r.best();
        assert_eq!(best.row_intra, Some(1.0), "winner {:?}", best.cand);
        assert!(best.cand.m1 <= 4, "winner {}x{}", best.cand.m1, best.cand.m2);
        // Every entry carries placement fractions in the opt-in path.
        assert!(r.entries.iter().all(|e| e.row_intra.is_some() && e.col_intra.is_some()));
        // Legacy path stays placement-free.
        let legacy = autotune([256, 256, 256], 16, &TuneOptions::default()).unwrap();
        assert!(legacy.entries.iter().all(|e| e.row_intra.is_none()));
    }

    #[test]
    fn truncation_scoring_lowers_every_candidate_score() {
        let base = TuneOptions {
            explore_use_even: false,
            explore_overlap: false,
            ..TuneOptions::default()
        };
        let full = autotune([64, 64, 64], 8, &base).unwrap();
        let pruned = autotune(
            [64, 64, 64],
            8,
            &TuneOptions { truncation: Some(crate::grid::Truncation::Spherical23), ..base },
        )
        .unwrap();
        assert_eq!(full.entries.len(), pruned.entries.len());
        // Same candidate set; every feasible grid at P=8 has wire traffic
        // on at least one axis, so pruning strictly lowers every score.
        for e in &pruned.entries {
            let f = full
                .entries
                .iter()
                .find(|x| x.cand == e.cand)
                .expect("candidate sets must match");
            assert!(e.model_s < f.model_s, "{:?}: {} !< {}", e.cand, e.model_s, f.model_s);
        }
    }

    #[test]
    fn best_chunks_is_interior_on_comm_heavy_problems() {
        let profile = MachineProfile::synthetic(Machine::cray_xt5());
        let k = best_chunks([2048, 2048, 2048], 32, 64, false, &profile, 16.0);
        assert!(k > 1, "overlap should pay on a comm-heavy run, got k={k}");
        assert!(k <= 16);
    }
}
