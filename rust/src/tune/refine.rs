//! Measured refinement: run the real pipeline briefly for the model's
//! top-K candidates and let wall-clock numbers settle the final ranking.
//!
//! The workload is a deterministic pseudo-random field derived from the
//! tuner seed (hash of the *global* coordinates, so every rank fills its
//! pencil identically regardless of the decomposition under test).

use crate::coordinator::{run_on_threads, PlanSpec};
use crate::grid::ProcGrid;
use crate::util::error::Result;
use crate::util::SplitMix64;

use super::candidates::Candidate;

/// Deterministic field value at global coordinates `(x, y, z)`.
pub fn seeded_field(seed: u64, x: usize, y: usize, z: usize) -> f64 {
    let key = seed
        ^ ((x as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15))
        ^ ((y as u64).wrapping_mul(0xC2B2_AE3D_27D4_EB4F))
        ^ ((z as u64).wrapping_mul(0x1656_67B1_9E37_79F9));
    SplitMix64::new(key).next_f64() - 0.5
}

/// Measure one candidate: `iters` forward+backward pairs on thread ranks
/// (one warmup pair discarded), returning max-over-ranks seconds per pair.
pub fn measure_candidate(
    dims: [usize; 3],
    cand: &Candidate,
    iters: usize,
    seed: u64,
) -> Result<f64> {
    let spec = PlanSpec::new(dims, ProcGrid::new(cand.m1, cand.m2))?
        .with_use_even(cand.use_even)
        .with_overlap_chunks(cand.overlap_chunks)?;
    let iters = iters.max(1);
    let report = run_on_threads(&spec, move |ctx| {
        let input = ctx.make_real_input(|x, y, z| seeded_field(seed, x, y, z));
        let mut out = ctx.alloc_output();
        let mut back = ctx.alloc_input();
        ctx.forward(&input, &mut out)?;
        ctx.backward(&out, &mut back)?;
        let t0 = std::time::Instant::now();
        for _ in 0..iters {
            ctx.forward(&input, &mut out)?;
            ctx.backward(&out, &mut back)?;
        }
        Ok(ctx.max_over_ranks(t0.elapsed().as_secs_f64() / iters as f64))
    })?;
    Ok(report.per_rank[0])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn seeded_field_is_deterministic_and_seed_sensitive() {
        let a = seeded_field(7, 1, 2, 3);
        assert_eq!(a, seeded_field(7, 1, 2, 3));
        assert_ne!(a, seeded_field(8, 1, 2, 3));
        assert_ne!(a, seeded_field(7, 2, 2, 3));
        assert!(a >= -0.5 && a < 0.5);
    }

    #[test]
    fn measure_candidate_returns_positive_time() {
        let c = Candidate { m1: 2, m2: 2, use_even: false, overlap_chunks: 2 };
        let t = measure_candidate([16, 16, 16], &c, 1, 42).unwrap();
        assert!(t > 0.0 && t < 60.0, "pair time {t}");
    }
}
