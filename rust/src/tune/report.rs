//! The ranked tuner output: every priced candidate, best first, plus the
//! concrete [`PlanSpec`] the winner resolves to.

use crate::bench::{FigureRow, Table};
use crate::coordinator::PlanSpec;
use crate::grid::ProcGrid;
use crate::util::error::Result;

use super::candidates::Candidate;

/// One ranked candidate with its scores.
#[derive(Debug, Clone)]
pub struct TuneEntry {
    pub cand: Candidate,
    /// Eq.-3 model prediction, seconds per forward transform. With
    /// topology-aware scoring this is the two-level, intra-node-first
    /// schedule prediction.
    pub model_s: f64,
    /// Measured seconds per forward+backward pair from the refinement
    /// runs (`None` when the candidate was ranked by model only).
    pub measured_s: Option<f64>,
    /// Average intra-node fraction of the ROW sub-communicators under the
    /// tuner's node map (`None` without topology-aware scoring). `1.0`
    /// means every ROW exchange stays on a node — the placement the
    /// tuner prefers whenever a feasible grid offers it.
    pub row_intra: Option<f64>,
    /// Average intra-node fraction of the COLUMN sub-communicators.
    pub col_intra: Option<f64>,
}

/// The tuner's full output: candidates best-first.
#[derive(Debug, Clone)]
pub struct TuneReport {
    pub dims: [usize; 3],
    pub nprocs: usize,
    /// Name of the machine profile the scores were computed on.
    pub profile: String,
    /// Seed the refinement workload was generated from.
    pub seed: u64,
    /// All priced candidates, best first. Refined candidates (those with
    /// `measured_s`) rank ahead of model-only ones, ordered by measured
    /// time; the rest follow ordered by model time.
    pub entries: Vec<TuneEntry>,
}

impl TuneReport {
    /// The winning candidate.
    pub fn best(&self) -> &TuneEntry {
        &self.entries[0]
    }

    /// Resolve the winner into a validated [`PlanSpec`].
    pub fn best_spec(&self) -> Result<PlanSpec> {
        let c = &self.best().cand;
        PlanSpec::new(self.dims, ProcGrid::new(c.m1, c.m2))?
            .with_use_even(c.use_even)
            .with_overlap_chunks(c.overlap_chunks)
    }

    /// Render the ranked candidate table (what `p3dfft tune` prints).
    pub fn render(&self) -> String {
        let mut table = self.to_table();
        table.title = format!(
            "tune: {}x{}x{} on P={} ranks, profile {}",
            self.dims[0], self.dims[1], self.dims[2], self.nprocs, self.profile
        );
        table.render()
    }

    /// The ranked candidates as a [`Table`] (shared by `render` and the
    /// CI bench-smoke JSON summary).
    pub fn to_table(&self) -> Table {
        let mut table = Table::new("tune");
        for (rank, e) in self.entries.iter().enumerate() {
            let mut row = FigureRow::new("candidate", e.cand.label())
                .col("rank", (rank + 1) as f64)
                .col("model_s", e.model_s);
            if let Some(m) = e.measured_s {
                row = row.col("measured_s", m);
            }
            if let (Some(r), Some(c)) = (e.row_intra, e.col_intra) {
                row = row.col("row_intra", r).col("col_intra", c);
            }
            table.push(row);
        }
        table
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn entry(m1: usize, m2: usize, model_s: f64) -> TuneEntry {
        TuneEntry {
            cand: Candidate { m1, m2, use_even: false, overlap_chunks: 1 },
            model_s,
            measured_s: None,
            row_intra: None,
            col_intra: None,
        }
    }

    #[test]
    fn placement_columns_render_when_present() {
        let mut e = entry(2, 2, 0.5);
        e.row_intra = Some(1.0);
        e.col_intra = Some(0.0);
        let r = TuneReport {
            dims: [32, 32, 32],
            nprocs: 4,
            profile: "test".into(),
            seed: 0,
            entries: vec![e],
        };
        let s = r.render();
        assert!(s.contains("row_intra"), "{s}");
        assert!(s.contains("col_intra"), "{s}");
    }

    #[test]
    fn best_spec_resolves_winner() {
        let r = TuneReport {
            dims: [32, 32, 32],
            nprocs: 4,
            profile: "test".into(),
            seed: 0,
            entries: vec![entry(1, 4, 0.5), entry(2, 2, 0.7)],
        };
        let spec = r.best_spec().unwrap();
        assert_eq!((spec.pgrid.m1, spec.pgrid.m2), (1, 4));
        assert_eq!(spec.opts.overlap_chunks, 1);
    }

    #[test]
    fn render_lists_candidates_ranked() {
        let r = TuneReport {
            dims: [32, 32, 32],
            nprocs: 4,
            profile: "test".into(),
            seed: 0,
            entries: vec![entry(1, 4, 0.5), entry(2, 2, 0.7)],
        };
        let s = r.render();
        assert!(s.contains("1x4"), "{s}");
        assert!(s.contains("2x2"), "{s}");
        assert!(s.contains("model_s"), "{s}");
    }
}
