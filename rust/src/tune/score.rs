//! Model scoring: price one candidate with the Eq.-3 machine model.

use crate::grid::{PruneRule, Truncation};
use crate::mpi::{CopyMode, NodeMap};
use crate::netmodel::{
    predict_pruned_overlapped, predict_pruned_two_level, ModelInput, TopoPrediction,
};

use super::candidates::Candidate;
use super::profile::MachineProfile;

/// `(row_keep, col_keep)` wire fractions for a truncated run of `dims`:
/// the share of each exchange's full-grid volume that still crosses the
/// wire once pruned packing ships only retained modes. `None` is the
/// full-grid transform, `(1.0, 1.0)`.
pub fn keep_fractions(dims: [usize; 3], truncation: Option<Truncation>) -> (f64, f64) {
    match truncation {
        Some(t) => {
            let r = PruneRule::new(dims, t);
            (r.row_fraction(), r.col_fraction())
        }
        None => (1.0, 1.0),
    }
}

fn input_of(
    dims: [usize; 3],
    cand: &Candidate,
    profile: &MachineProfile,
    elem_bytes: f64,
    copy: CopyMode,
) -> ModelInput {
    ModelInput {
        nx: dims[0],
        ny: dims[1],
        nz: dims[2],
        m1: cand.m1,
        m2: cand.m2,
        elem_bytes,
        use_even: cand.use_even,
        copy,
        machine: profile.machine.clone(),
    }
}

/// Predicted seconds for one forward transform of `dims` under `cand` on
/// `profile`'s machine. `overlap_chunks = 1` reproduces the blocking
/// `predict(..).total()` exactly; larger counts use the Eq.-1-style
/// pipelined prediction, so the chunk optimum the executor exposes is the
/// one the tuner ranks by.
pub fn model_seconds(
    dims: [usize; 3],
    cand: &Candidate,
    profile: &MachineProfile,
    elem_bytes: f64,
) -> f64 {
    model_seconds_pruned(dims, cand, profile, elem_bytes, (1.0, 1.0))
}

/// [`model_seconds`] with pruned-volume wire pricing: each exchange term
/// is scaled by its [`keep_fractions`] share before pipelining. `keep =
/// (1.0, 1.0)` reproduces [`model_seconds`] bit for bit, so the untruncated
/// tuner ranking is unchanged.
pub fn model_seconds_pruned(
    dims: [usize; 3],
    cand: &Candidate,
    profile: &MachineProfile,
    elem_bytes: f64,
    keep: (f64, f64),
) -> f64 {
    // The single-level law is copy-blind (it has no intra/inter split to
    // discount), so the discipline passed here is immaterial.
    let input = input_of(dims, cand, profile, elem_bytes, CopyMode::Mailbox);
    predict_pruned_overlapped(&input, cand.overlap_chunks, keep.0, keep.1)
}

/// Price one candidate under an explicit node map, with the
/// topology-aware (intra-node-first) exchange schedule the runtime now
/// implements. Returns the full [`TopoPrediction`] so the tuner can
/// surface the `(m1, m2)` placement fractions alongside the score. Only
/// the opt-in topology path uses this; [`model_seconds`] is unchanged.
pub fn model_seconds_two_level(
    dims: [usize; 3],
    cand: &Candidate,
    profile: &MachineProfile,
    elem_bytes: f64,
    nodes: &NodeMap,
    copy: CopyMode,
) -> TopoPrediction {
    model_seconds_pruned_two_level(dims, cand, profile, elem_bytes, nodes, (1.0, 1.0), copy)
}

/// [`model_seconds_two_level`] with pruned-volume wire pricing (see
/// [`model_seconds_pruned`]). `copy` is the exchange discipline the run
/// will use: the two-level law prices intra-node traffic at two memory
/// streams per block under the mailbox and one under single-copy windows,
/// which shifts the placement optimum toward on-node rows even further
/// when single-copy is active.
#[allow(clippy::too_many_arguments)]
pub fn model_seconds_pruned_two_level(
    dims: [usize; 3],
    cand: &Candidate,
    profile: &MachineProfile,
    elem_bytes: f64,
    nodes: &NodeMap,
    keep: (f64, f64),
    copy: CopyMode,
) -> TopoPrediction {
    let input = input_of(dims, cand, profile, elem_bytes, copy);
    predict_pruned_two_level(&input, cand.overlap_chunks, nodes, keep.0, keep.1)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::netmodel::{predict, Machine};

    fn cand(m1: usize, m2: usize, use_even: bool, k: usize) -> Candidate {
        Candidate { m1, m2, use_even, overlap_chunks: k }
    }

    #[test]
    fn k1_matches_blocking_prediction() {
        let profile = MachineProfile::synthetic(Machine::cray_xt5());
        let dims = [256, 256, 256];
        let s = model_seconds(dims, &cand(4, 8, false, 1), &profile, 16.0);
        let input = ModelInput {
            nx: 256,
            ny: 256,
            nz: 256,
            m1: 4,
            m2: 8,
            elem_bytes: 16.0,
            use_even: false,
            copy: CopyMode::Mailbox,
            machine: Machine::cray_xt5(),
        };
        let total = predict(&input).total();
        assert!((s - total).abs() < 1e-12 * total);
    }

    #[test]
    fn pruned_keep_fractions_and_scoring() {
        // 2/3-rule sphere on a cube keeps ~2/3 of the x prefix and ~1/3
        // of (kx, ky) pairs — both wire terms shrink, nothing else moves.
        let dims = [64, 64, 64];
        let (r, c) = keep_fractions(dims, Some(Truncation::Spherical23));
        assert!(r > 0.6 && r < 0.7, "row keep {r}");
        assert!(c > 0.2 && c < 0.4, "col keep {c}");
        assert_eq!(keep_fractions(dims, None), (1.0, 1.0));

        let profile = MachineProfile::synthetic(Machine::cray_xt5());
        let cd = cand(4, 8, false, 1);
        let full = model_seconds(dims, &cd, &profile, 16.0);
        assert_eq!(model_seconds_pruned(dims, &cd, &profile, 16.0, (1.0, 1.0)), full);
        assert!(model_seconds_pruned(dims, &cd, &profile, 16.0, (r, c)) < full);
    }

    #[test]
    fn useeven_discount_shows_up_on_cray() {
        let profile = MachineProfile::synthetic(Machine::cray_xt5());
        let dims = [2048, 2048, 2048];
        let v = model_seconds(dims, &cand(12, 128, false, 1), &profile, 16.0);
        let e = model_seconds(dims, &cand(12, 128, true, 1), &profile, 16.0);
        assert!(e < v, "useeven {e} vs alltoallv {v}");
    }
}
