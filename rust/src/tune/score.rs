//! Model scoring: price one candidate with the Eq.-3 machine model.

use crate::mpi::NodeMap;
use crate::netmodel::{predict_overlapped, predict_two_level, ModelInput, TopoPrediction};

use super::candidates::Candidate;
use super::profile::MachineProfile;

/// Predicted seconds for one forward transform of `dims` under `cand` on
/// `profile`'s machine. `overlap_chunks = 1` reproduces the blocking
/// `predict(..).total()` exactly; larger counts use the Eq.-1-style
/// pipelined prediction, so the chunk optimum the executor exposes is the
/// one the tuner ranks by.
pub fn model_seconds(
    dims: [usize; 3],
    cand: &Candidate,
    profile: &MachineProfile,
    elem_bytes: f64,
) -> f64 {
    let input = ModelInput {
        nx: dims[0],
        ny: dims[1],
        nz: dims[2],
        m1: cand.m1,
        m2: cand.m2,
        elem_bytes,
        use_even: cand.use_even,
        machine: profile.machine.clone(),
    };
    predict_overlapped(&input, cand.overlap_chunks)
}

/// Price one candidate under an explicit node map, with the
/// topology-aware (intra-node-first) exchange schedule the runtime now
/// implements. Returns the full [`TopoPrediction`] so the tuner can
/// surface the `(m1, m2)` placement fractions alongside the score. Only
/// the opt-in topology path uses this; [`model_seconds`] is unchanged.
pub fn model_seconds_two_level(
    dims: [usize; 3],
    cand: &Candidate,
    profile: &MachineProfile,
    elem_bytes: f64,
    nodes: &NodeMap,
) -> TopoPrediction {
    let input = ModelInput {
        nx: dims[0],
        ny: dims[1],
        nz: dims[2],
        m1: cand.m1,
        m2: cand.m2,
        elem_bytes,
        use_even: cand.use_even,
        machine: profile.machine.clone(),
    };
    predict_two_level(&input, cand.overlap_chunks, nodes)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::netmodel::{predict, Machine};

    fn cand(m1: usize, m2: usize, use_even: bool, k: usize) -> Candidate {
        Candidate { m1, m2, use_even, overlap_chunks: k }
    }

    #[test]
    fn k1_matches_blocking_prediction() {
        let profile = MachineProfile::synthetic(Machine::cray_xt5());
        let dims = [256, 256, 256];
        let s = model_seconds(dims, &cand(4, 8, false, 1), &profile, 16.0);
        let input = ModelInput {
            nx: 256,
            ny: 256,
            nz: 256,
            m1: 4,
            m2: 8,
            elem_bytes: 16.0,
            use_even: false,
            machine: Machine::cray_xt5(),
        };
        let total = predict(&input).total();
        assert!((s - total).abs() < 1e-12 * total);
    }

    #[test]
    fn useeven_discount_shows_up_on_cray() {
        let profile = MachineProfile::synthetic(Machine::cray_xt5());
        let dims = [2048, 2048, 2048];
        let v = model_seconds(dims, &cand(12, 128, false, 1), &profile, 16.0);
        let e = model_seconds(dims, &cand(12, 128, true, 1), &profile, 16.0);
        assert!(e < v, "useeven {e} vs alltoallv {v}");
    }
}
