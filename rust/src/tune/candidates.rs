//! Candidate enumeration: every knob combination the tuner prices.
//!
//! The `(m1, m2)` space is the divisor-pair lattice of P filtered by the
//! paper's Eq.-2 feasibility constraints (no rank may own an empty pencil
//! in any orientation — checked through [`Decomp::new`], the same
//! validation a real plan goes through). Overlap chunk counts are the
//! powers of two up to the shortest invariant axis (more chunks than
//! planes just clamp in the executor, so pricing them adds nothing).

use crate::grid::{Decomp, ProcGrid};

/// One point of the tuning space.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Candidate {
    pub m1: usize,
    pub m2: usize,
    /// USEEVEN: padded `alltoall` instead of `alltoallv`.
    pub use_even: bool,
    /// Communication–compute overlap chunk count (1 = blocking).
    pub overlap_chunks: usize,
}

impl Candidate {
    pub fn p(&self) -> usize {
        self.m1 * self.m2
    }

    /// "2x8 even k=4" — the label the ranked table prints.
    pub fn label(&self) -> String {
        format!(
            "{}x{}{} k={}",
            self.m1,
            self.m2,
            if self.use_even { " even" } else { "" },
            self.overlap_chunks
        )
    }
}

/// All Eq.-2-feasible processor grids with `m1 * m2 == p` for `dims`.
pub fn grid_candidates(dims: [usize; 3], p: usize) -> Vec<ProcGrid> {
    ProcGrid::factorizations(p)
        .into_iter()
        .filter(|pg| Decomp::new(dims[0], dims[1], dims[2], *pg).is_ok())
        .collect()
}

/// Overlap chunk counts worth pricing for `dims`: 1 plus powers of two up
/// to the shortest chunkable axis (z-slabs for X↔Y, x-slabs for Y↔Z),
/// capped at 16 — past that the per-chunk message latency always loses.
/// This is the *global* ladder; [`enumerate`] additionally clamps each
/// candidate to [`max_executable_chunks`] for its grid.
pub fn chunk_candidates(dims: [usize; 3]) -> Vec<usize> {
    let h = dims[0] / 2 + 1;
    let cap = dims[2].min(h).clamp(1, 16);
    let mut out = vec![1usize];
    let mut k = 2usize;
    while k <= cap {
        out.push(k);
        k *= 2;
    }
    out
}

/// Largest overlap chunk count the executor can actually run on the
/// `m1 x m2` grid: each transpose clamps its chunk plan to the *per-rank*
/// local extent of the invariant axis — z-slabs `nz/m2` for X↔Y and
/// spectral-x slabs `h/m1` for Y↔Z. Pricing a larger `k` would model a
/// pipeline depth the real run silently reduces.
pub fn max_executable_chunks(dims: [usize; 3], m1: usize, m2: usize) -> usize {
    let h = dims[0] / 2 + 1;
    ((dims[2] / m2.max(1)).min(h / m1.max(1))).max(1)
}

/// The full candidate cross product for one problem: every feasible grid
/// crossed with the given `use_even` and `overlap_chunks` settings (the
/// caller decides whether each knob is pinned to one value or explored).
/// Chunk counts are clamped per grid to [`max_executable_chunks`] and
/// deduplicated, so every candidate's `overlap_chunks` is one the
/// executor will actually run.
pub fn enumerate(dims: [usize; 3], p: usize, evens: &[bool], chunks: &[usize]) -> Vec<Candidate> {
    let mut out = Vec::new();
    for pg in grid_candidates(dims, p) {
        let cap = max_executable_chunks(dims, pg.m1, pg.m2);
        for &use_even in evens {
            let mut seen: Vec<usize> = Vec::with_capacity(chunks.len());
            for &k in chunks {
                let overlap_chunks = k.min(cap);
                if seen.contains(&overlap_chunks) {
                    continue;
                }
                seen.push(overlap_chunks);
                out.push(Candidate { m1: pg.m1, m2: pg.m2, use_even, overlap_chunks });
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grids_are_exactly_feasible_divisor_pairs() {
        // 64^3 on P=12: all six divisor pairs are feasible.
        let grids = grid_candidates([64, 64, 64], 12);
        let pairs: Vec<(usize, usize)> = grids.iter().map(|g| (g.m1, g.m2)).collect();
        assert_eq!(pairs, vec![(1, 12), (2, 6), (3, 4), (4, 3), (6, 2), (12, 1)]);
    }

    #[test]
    fn eq2_violations_are_rejected() {
        // dims [8, 8, 64]: h = 5, so m1 <= 5 and m2 <= min(8, 64) = 8.
        let grids = grid_candidates([8, 8, 64], 16);
        for g in &grids {
            assert!(g.m1 <= 5 && g.m2 <= 8, "infeasible {}x{} survived", g.m1, g.m2);
        }
        // 16x1 and 1x16 both violate Eq. 2 here; only 2x8 and 4x4 remain.
        let pairs: Vec<(usize, usize)> = grids.iter().map(|g| (g.m1, g.m2)).collect();
        assert_eq!(pairs, vec![(2, 8), (4, 4)]);
    }

    #[test]
    fn chunk_candidates_capped_by_axes() {
        assert_eq!(chunk_candidates([64, 64, 64]), vec![1, 2, 4, 8, 16]);
        // nz = 4 caps the ladder.
        assert_eq!(chunk_candidates([64, 64, 4]), vec![1, 2, 4]);
        // h = 2 caps it from the X side.
        assert_eq!(chunk_candidates([3, 64, 64]), vec![1, 2]);
    }

    #[test]
    fn enumerate_crosses_all_knobs() {
        let cands = enumerate([64, 64, 64], 4, &[false, true], &[1, 2, 4, 8, 16]);
        // Grids 1x4 and 2x2 admit all 5 chunk counts; 4x1 clamps to
        // h/m1 = 8 (16 -> 8, deduplicated), leaving 4. Times 2 use_even.
        assert_eq!(cands.len(), (5 + 5 + 4) * 2);
        let pinned = enumerate([64, 64, 64], 4, &[true], &[4]);
        assert_eq!(pinned.len(), 3);
        assert!(pinned.iter().all(|c| c.use_even && c.overlap_chunks == 4));
    }

    #[test]
    fn enumerate_clamps_chunks_to_executable_depth() {
        // dims [64,64,64], grid 16x2: YZ transpose clamps to h/m1 = 33/16
        // = 2 slabs per rank — no candidate may price more chunks.
        assert_eq!(max_executable_chunks([64, 64, 64], 16, 2), 2);
        let cands = enumerate([64, 64, 64], 32, &[false], &[1, 2, 4, 8, 16]);
        for c in cands.iter().filter(|c| c.m1 == 16) {
            assert!(c.overlap_chunks <= 2, "{c:?} exceeds executable depth");
        }
        // And the clamped ladder is deduplicated.
        let sixteen: Vec<usize> =
            cands.iter().filter(|c| c.m1 == 16).map(|c| c.overlap_chunks).collect();
        assert_eq!(sixteen, vec![1, 2]);
    }
}
