//! Machine profiles for the plan-time tuner.
//!
//! The tuner scores candidates with [`crate::netmodel::predict`], which
//! needs a [`Machine`]. A profile is either *synthetic* (one of the named
//! paper machines, or a fixed nominal host — deterministic, used by tests
//! and the figure benches) or *calibrated* (constants measured on this
//! host by fast in-process micro-probes of the library's own kernels, the
//! same kernels the `calib_*` benches time at full size).

use crate::netmodel::calibrate::{measure_alltoall_bw, measure_fft_flops, measure_pack_bw};
use crate::netmodel::{Interconnect, Machine};
use crate::tile::TILE_LANES;

/// Where a profile's constants came from.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ProfileSource {
    /// Fixed constants: paper machine presets or the nominal host. Fully
    /// deterministic — two tuner runs over the same synthetic profile
    /// produce bit-identical rankings.
    Synthetic,
    /// Constants measured on this host by micro-probes.
    Calibrated,
}

/// A named machine description the tuner prices candidates against.
#[derive(Debug, Clone)]
pub struct MachineProfile {
    /// Display name (e.g. "localhost (nominal)", "Cray XT5").
    pub name: String,
    /// The Eq.-3 machine model fed to `netmodel::predict`.
    pub machine: Machine,
    pub source: ProfileSource,
}

impl MachineProfile {
    /// Wrap a paper-machine preset (or any hand-built [`Machine`]) as a
    /// fixed synthetic profile.
    pub fn synthetic(machine: Machine) -> Self {
        MachineProfile { name: machine.name.to_string(), machine, source: ProfileSource::Synthetic }
    }

    /// A fixed single-node host profile with nominal constants (1 Gflop/s
    /// per core, 4 GB/s per-task streaming). Deterministic; the default
    /// for tests and for `fig_tune`'s model-side pick.
    pub fn nominal_host() -> Self {
        MachineProfile {
            name: "localhost (nominal)".to_string(),
            machine: Machine::localhost(1.0e9, 4.0e9),
            source: ProfileSource::Synthetic,
        }
    }

    /// Calibrate a host profile from in-process micro-probes: the serial
    /// FFT kernel for F, the STRIDE1 pack/unpack kernels for σ_mem, and a
    /// thread-fabric `alltoall` for the exchange bandwidth — the same
    /// kernels behind the `calib_local_fft`, `calib_pack` and
    /// `calib_alltoall` benches, run at reduced size (a few ms total).
    ///
    /// The FFT probe batch (`2·W + W/2` lines, `W =`
    /// [`TILE_LANES`]) deliberately covers two full lane-interleaved
    /// tiles of the blocked driver — executed through the plan's
    /// dispatched SIMD backend, so F prices the kernels the pencil stages
    /// actually run on this host — plus a ragged scalar tail, keeping the
    /// blocked/tail mix representative at any sweep width.
    pub fn calibrated_quick() -> Self {
        Self::calibrated_with(128, 2 * TILE_LANES + TILE_LANES / 2, 8, 48, 2, 8 * 1024)
    }

    /// Calibrate with explicit probe sizes (FFT length/batch, pack
    /// nz/n, alltoall ranks/block-doubles).
    pub fn calibrated_with(
        fft_n: usize,
        fft_batch: usize,
        pack_nz: usize,
        pack_n: usize,
        a2a_ranks: usize,
        a2a_block: usize,
    ) -> Self {
        let fft_flops = measure_fft_flops(fft_n, fft_batch);
        let pack_bw = measure_pack_bw(pack_nz, pack_n);
        let fabric_bw = measure_alltoall_bw(a2a_ranks, a2a_block);
        let mut machine = Machine::localhost(fft_flops, pack_bw);
        // The probe reports *aggregate* off-rank bandwidth over
        // `a2a_ranks`; Clos `port_bw` is per-node injection bandwidth
        // (bisection_bw multiplies by node count), so divide the rank
        // count out or it would be counted twice.
        let port_bw = fabric_bw / a2a_ranks.max(1) as f64;
        machine.interconnect = Interconnect::Clos { port_bw, cores_per_node: 1 };
        // One "node" per rank: Machine::localhost's cores_per_node of
        // usize::MAX would route every exchange through the memory-
        // bandwidth branch of the model and the measured fabric bandwidth
        // would never be read; with cores_per_node = 1 inter-rank
        // exchanges are priced through the Clos law above.
        machine.cores_per_node = 1;
        MachineProfile {
            name: "localhost (calibrated)".to_string(),
            machine,
            source: ProfileSource::Calibrated,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn nominal_host_is_synthetic_and_fixed() {
        let a = MachineProfile::nominal_host();
        let b = MachineProfile::nominal_host();
        assert_eq!(a.source, ProfileSource::Synthetic);
        assert_eq!(a.machine.flops_per_core, b.machine.flops_per_core);
        assert_eq!(a.machine.mem_bw_per_task, b.machine.mem_bw_per_task);
    }

    #[test]
    fn synthetic_wraps_paper_presets() {
        let p = MachineProfile::synthetic(Machine::cray_xt5());
        assert_eq!(p.name, "Cray XT5");
        assert_eq!(p.source, ProfileSource::Synthetic);
        assert!(p.machine.alltoallv_penalty > 1.0);
    }

    #[test]
    fn calibrated_quick_produces_sane_constants() {
        let p = MachineProfile::calibrated_quick();
        assert_eq!(p.source, ProfileSource::Calibrated);
        assert!(p.machine.flops_per_core > 1.0e6, "{:.3e}", p.machine.flops_per_core);
        assert!(p.machine.mem_bw_per_task > 1.0e6, "{:.3e}", p.machine.mem_bw_per_task);
        // The measured fabric bandwidth must actually reach the model:
        // with one "node" per rank, exchanges take the bisection branch.
        assert_eq!(p.machine.cores_per_node, 1);
        assert!(p.machine.interconnect.bisection_bw(4) > 0.0);
    }
}
