//! The paper's benchmark workload (`test_sine`, §4.1): initialise a 3D
//! sine field, run forward+backward, verify the result equals the input
//! up to the known scale factor, report loop-averaged timings.

use crate::fft::Real;

/// The `test_sine` initial condition at global coordinates — a product of
/// sines, smooth and with a known sparse spectrum.
pub fn sine_field<T: Real>(nx: usize, ny: usize, nz: usize) -> impl Fn(usize, usize, usize) -> T {
    move |x, y, z| {
        let fx = T::from_usize(x).unwrap() / T::from_usize(nx).unwrap();
        let fy = T::from_usize(y).unwrap() / T::from_usize(ny).unwrap();
        let fz = T::from_usize(z).unwrap() / T::from_usize(nz).unwrap();
        let two_pi = T::PI() + T::PI();
        (two_pi * fx).sin() * (two_pi * fy).sin() * (two_pi * fz).sin()
    }
}

/// Max-abs error between the roundtripped field (already divided by the
/// normalisation) and the original input. The paper's sample "checks to
/// make sure the data is the same (apart from a scale factor)".
pub fn verify_roundtrip<T: Real>(original: &[T], roundtripped: &[T], norm: T) -> f64 {
    assert_eq!(original.len(), roundtripped.len());
    let mut max_err = 0.0f64;
    for (o, r) in original.iter().zip(roundtripped) {
        let err = (*r / norm - *o).to_f64().unwrap().abs();
        if err > max_err {
            max_err = err;
        }
    }
    max_err
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sine_field_is_zero_on_planes() {
        let f = sine_field::<f64>(8, 8, 8);
        assert!(f(0, 3, 5).abs() < 1e-12);
        assert!(f(3, 0, 5).abs() < 1e-12);
        assert!(f(3, 5, 4).abs() < 1e-12); // sin(pi) = 0 at z = nz/2
    }

    #[test]
    fn sine_field_nontrivial_in_interior() {
        let f = sine_field::<f64>(8, 8, 8);
        assert!(f(2, 2, 2).abs() > 0.1);
    }

    #[test]
    fn verify_roundtrip_scales() {
        let orig = vec![1.0f64, -2.0, 0.5];
        let rt: Vec<f64> = orig.iter().map(|v| v * 8.0).collect();
        assert!(verify_roundtrip(&orig, &rt, 8.0) < 1e-15);
        assert!(verify_roundtrip(&orig, &rt, 4.0) > 0.4);
    }
}
