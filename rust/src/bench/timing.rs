//! Timing loops with warmup and robust statistics.

use std::time::Instant;

use crate::util::stats::Summary;

/// Options for a measurement loop.
#[derive(Debug, Clone, Copy)]
pub struct MeasureOpts {
    pub warmup: usize,
    pub iterations: usize,
}

impl Default for MeasureOpts {
    fn default() -> Self {
        MeasureOpts { warmup: 1, iterations: 5 }
    }
}

/// Time `f` (seconds per call) with warmup discards; returns robust stats.
pub fn measure(opts: MeasureOpts, mut f: impl FnMut()) -> Summary {
    for _ in 0..opts.warmup {
        f();
    }
    let mut samples = Vec::with_capacity(opts.iterations.max(1));
    for _ in 0..opts.iterations.max(1) {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64());
    }
    Summary::from_samples(&samples)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_sleep_duration() {
        let s = measure(MeasureOpts { warmup: 0, iterations: 3 }, || {
            std::thread::sleep(std::time::Duration::from_millis(3));
        });
        assert!(s.median >= 0.002, "median {}", s.median);
        assert_eq!(s.n, 3);
    }

    #[test]
    fn warmup_calls_happen() {
        let mut calls = 0;
        let _ = measure(MeasureOpts { warmup: 2, iterations: 1 }, || calls += 1);
        assert_eq!(calls, 3);
    }
}
