//! Shared generators for the paper's figures (used by `rust/benches/fig*`).
//!
//! Every figure bench combines (a) *measured* rows from real multi-rank
//! runs at host scale and (b) *model* rows at paper scale from the
//! Eq. 3 machine model. This module holds the common protocol code.

use crate::bench::figures::{FigureRow, Table};
use crate::bench::workload::sine_field;
use crate::coordinator::{run_on_threads, PlanSpec};
use crate::grid::ProcGrid;
use crate::netmodel::model::{tflops_pair, weak_efficiency};
use crate::netmodel::{fit_strong_scaling, predict, FitResult, Machine, ModelInput};
use crate::util::error::Result;

/// Best (lowest-total-time) processor grid for `p` cores on `machine`
/// under the model — the paper's "only the best M1 x M2 combination is
/// taken as data point for each core count".
pub fn best_pgrid(n: usize, p: usize, machine: &Machine, use_even: bool) -> (usize, usize, f64) {
    let mut best = (1, p, f64::INFINITY);
    for pg in ProcGrid::factorizations(p) {
        // Eq. 2 feasibility.
        let h = n / 2 + 1;
        if pg.m1 > n.min(h) || pg.m2 > n {
            continue;
        }
        let mut input = ModelInput::cubic(n, pg.m1, pg.m2, machine.clone());
        input.use_even = use_even;
        let t = 2.0 * predict(&input).total();
        if t < best.2 {
            best = (pg.m1, pg.m2, t);
        }
    }
    best
}

/// Best geometry restricted to *true* 2D pencils (both factors >= 2, so
/// neither exchange degenerates) — the "2d" series of Fig. 10, where
/// comparing against 1 x P slabs is the point.
pub fn best_pgrid_2d(n: usize, p: usize, machine: &Machine, use_even: bool) -> (usize, usize, f64) {
    let mut best = (0, 0, f64::INFINITY);
    for pg in ProcGrid::factorizations(p) {
        let h = n / 2 + 1;
        if pg.m1 < 2 || pg.m2 < 2 || pg.m1 > n.min(h) || pg.m2 > n {
            continue;
        }
        let mut input = ModelInput::cubic(n, pg.m1, pg.m2, machine.clone());
        input.use_even = use_even;
        let t = 2.0 * predict(&input).total();
        if t < best.2 {
            best = (pg.m1, pg.m2, t);
        }
    }
    best
}

/// One strong-scaling series at paper scale: per core count, the best
/// geometry under both exchange options, plus comm time and TFLOPS —
/// the full content of Figs. 4-8.
pub fn strong_scaling_table(title: &str, n: usize, ps: &[usize], machine: &Machine) -> Table {
    let mut table = Table::new(title);
    let mut fit_ps = Vec::new();
    let mut fit_ts = Vec::new();
    for &p in ps {
        let (m1v, m2v, t_v) = best_pgrid(n, p, machine, false);
        let (m1e, m2e, t_e) = best_pgrid(n, p, machine, true);
        let mut inp = ModelInput::cubic(n, m1v, m2v, machine.clone());
        let comm = 2.0 * predict(&inp).comm();
        inp.use_even = true;
        table.push(
            FigureRow::new("alltoallv", format!("{p}"))
                .col("pair_s", t_v)
                .col("tflops", tflops_pair(&inp, t_v))
                .col("m1", m1v as f64)
                .col("m2", m2v as f64),
        );
        table.push(
            FigureRow::new("alltoall(useeven)", format!("{p}"))
                .col("pair_s", t_e)
                .col("tflops", tflops_pair(&inp, t_e))
                .col("m1", m1e as f64)
                .col("m2", m2e as f64),
        );
        table.push(FigureRow::new("comm(alltoallv)", format!("{p}")).col("pair_s", comm));
        fit_ps.push(p as f64);
        fit_ts.push(t_v);
    }
    // The paper's Eq. 4 fit to the alltoallv series.
    let fit = fit_strong_scaling(&fit_ps, &fit_ts, machine.interconnect.exponent());
    for (&p, _) in fit_ps.iter().zip(&fit_ts) {
        table.push(FigureRow::new("fit a/P+d/P^e", format!("{p}")).col("pair_s", fit.predict(p)));
    }
    table
}

/// The Eq. 4 fit for a strong-scaling series (exposed for benches that
/// also report the effective bisection bandwidth, §4.3).
pub fn strong_scaling_fit(n: usize, ps: &[usize], machine: &Machine) -> FitResult {
    let ts: Vec<f64> =
        ps.iter().map(|&p| best_pgrid(n, p, machine, false).2).collect();
    let psf: Vec<f64> = ps.iter().map(|&p| p as f64).collect();
    fit_strong_scaling(&psf, &ts, machine.interconnect.exponent())
}

/// Measured strong-scaling rows on this host (thread ranks).
pub fn measured_strong_rows(
    n: usize,
    pgrids: &[(usize, usize)],
    iterations: usize,
) -> Result<Vec<FigureRow>> {
    let mut rows = Vec::new();
    for &(m1, m2) in pgrids {
        let spec = match PlanSpec::new([n, n, n], ProcGrid::new(m1, m2)) {
            Ok(s) => s,
            Err(_) => continue,
        };
        let iters = iterations.max(1);
        let report = run_on_threads(&spec, move |ctx| {
            let input = ctx.make_real_input(sine_field::<f64>(n, n, n));
            let mut out = ctx.alloc_output();
            let mut back = ctx.alloc_input();
            ctx.forward(&input, &mut out)?; // warmup
            ctx.backward(&out, &mut back)?;
            let t0 = std::time::Instant::now();
            for _ in 0..iters {
                ctx.forward(&input, &mut out)?;
                ctx.backward(&out, &mut back)?;
            }
            Ok(ctx.max_over_ranks(t0.elapsed().as_secs_f64() / iters as f64))
        })?;
        rows.push(
            FigureRow::new("measured", format!("{} ({m1}x{m2})", m1 * m2))
                .col("pair_s", report.per_rank[0])
                .col("comm_s", report.comm())
                .col("compute_s", report.compute()),
        );
    }
    Ok(rows)
}

/// The paper's weak-scaling series (Fig. 9) under a machine model.
/// Returns the table and the 128→65536 efficiency (paper: 45%).
pub fn weak_scaling_table(machine: &Machine) -> (Table, f64) {
    let series: [(usize, usize); 5] =
        [(512, 16), (1024, 128), (2048, 1024), (4096, 8192), (8192, 65536)];
    let mut table = Table::new(format!("Fig. 9 weak scaling on {} (model)", machine.name));
    let mut pts = Vec::new();
    for &(n, p) in &series {
        let (m1, m2, pair) = best_pgrid(n, p, machine, true);
        table.push(
            FigureRow::new("model", format!("{n}^3@{p}"))
                .col("pair_s", pair)
                .col("m1", m1 as f64)
                .col("m2", m2 as f64),
        );
        pts.push((n, p, pair));
    }
    let (n1, p1, t1) = pts[1];
    let (n2, p2, t2) = pts[4];
    (table, weak_efficiency(n1, p1, t1, n2, p2, t2))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn best_pgrid_respects_eq2() {
        let m = Machine::cray_xt5();
        let (m1, m2, t) = best_pgrid(2048, 1024, &m, false);
        assert_eq!(m1 * m2, 1024);
        assert!(m1 <= 1025 && m2 <= 2048);
        assert!(t.is_finite());
    }

    #[test]
    fn strong_scaling_table_has_all_series() {
        let m = Machine::cray_xt5();
        let t = strong_scaling_table("test", 1024, &[256, 1024, 4096], &m);
        let series: std::collections::HashSet<&str> =
            t.rows.iter().map(|r| r.series.as_str()).collect();
        assert!(series.contains("alltoallv"));
        assert!(series.contains("alltoall(useeven)"));
        assert!(series.contains("comm(alltoallv)"));
        assert!(series.contains("fit a/P+d/P^e"));
    }

    #[test]
    fn weak_scaling_efficiency_in_papers_band() {
        let (_, eff) = weak_scaling_table(&Machine::cray_xt5());
        assert!(eff > 0.25 && eff < 0.75, "efficiency {eff}");
    }

    #[test]
    fn useeven_never_loses_on_cray_model() {
        let m = Machine::cray_xt5();
        for p in [1024usize, 8192] {
            let (_, _, tv) = best_pgrid(4096, p, &m, false);
            let (_, _, te) = best_pgrid(4096, p, &m, true);
            assert!(te <= tv * 1.0001, "p={p}: useeven {te} vs alltoallv {tv}");
        }
    }
}
