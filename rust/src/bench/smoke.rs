//! CI bench-smoke support: quick-mode detection and the JSON summary the
//! workflow uploads as the `BENCH_ci.json` artifact.
//!
//! Quick mode (`--quick` argv flag or `P3DFFT_BENCH_QUICK=1`) tells the
//! figure benches to shrink their measured sweeps to a few seconds total
//! so every PR gets a perf data point. When `P3DFFT_BENCH_JSON=PATH` is
//! set, each bench appends its tables to `PATH` as one JSON object per
//! line (the workflow wraps the lines into a single JSON array with `jq`).

use std::io::Write;

use super::figures::Table;

/// Environment variable enabling quick mode (any non-empty value but "0").
pub const QUICK_ENV: &str = "P3DFFT_BENCH_QUICK";
/// Environment variable naming the JSON-lines summary file.
pub const JSON_ENV: &str = "P3DFFT_BENCH_JSON";

/// True when the bench should run its reduced CI-smoke protocol.
pub fn quick_mode() -> bool {
    quick_from(
        std::env::args().any(|a| a == "--quick"),
        std::env::var(QUICK_ENV).ok().as_deref(),
    )
}

/// Pure core of [`quick_mode`] (tests exercise this directly — mutating
/// the process environment from parallel test threads is a data race).
fn quick_from(argv_flag: bool, env_value: Option<&str>) -> bool {
    argv_flag || env_value.map(|v| !v.is_empty() && v != "0").unwrap_or(false)
}

/// Append `table` to the `P3DFFT_BENCH_JSON` file (one JSON object per
/// line), tagged with the bench name. A no-op when the variable is unset;
/// I/O errors are reported to stderr but never fail the bench.
pub fn emit_json(bench: &str, table: &Table) {
    emit_json_to(std::env::var(JSON_ENV).ok().as_deref(), bench, table);
}

/// Pure core of [`emit_json`]: `path = None` (unset) or empty is a no-op.
fn emit_json_to(path: Option<&str>, bench: &str, table: &Table) {
    let Some(path) = path else { return };
    if path.is_empty() {
        return;
    }
    let line = table.to_json(bench);
    let result = std::fs::OpenOptions::new()
        .create(true)
        .append(true)
        .open(path)
        .and_then(|mut f| writeln!(f, "{line}"));
    if let Err(e) = result {
        eprintln!("warning: could not append bench JSON to {path}: {e}");
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bench::FigureRow;

    #[test]
    fn quick_from_reads_flag_and_env() {
        assert!(!quick_from(false, None));
        assert!(quick_from(true, None));
        assert!(quick_from(false, Some("1")));
        assert!(quick_from(false, Some("yes")));
        assert!(!quick_from(false, Some("0")));
        assert!(!quick_from(false, Some("")));
    }

    #[test]
    fn emit_json_to_appends_one_line_per_table() {
        let dir = std::env::temp_dir();
        let path = dir.join(format!("p3dfft_smoke_{}.jsonl", std::process::id()));
        let _ = std::fs::remove_file(&path);
        let path_str = path.to_str().unwrap();
        let mut t = Table::new("smoke");
        t.push(FigureRow::new("s", "x").col("v", 2.0));
        emit_json_to(Some(path_str), "b1", &t);
        emit_json_to(Some(path_str), "b2", &t);
        // Unset / empty are no-ops.
        emit_json_to(None, "b3", &t);
        emit_json_to(Some(""), "b3", &t);
        let text = std::fs::read_to_string(&path).unwrap();
        let _ = std::fs::remove_file(&path);
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 2);
        assert!(lines[0].contains("\"bench\":\"b1\""));
        assert!(lines[1].contains("\"bench\":\"b2\""));
    }
}
