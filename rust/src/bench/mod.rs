//! Benchmark harness (criterion is unavailable offline): robust timing,
//! the paper's `test_sine` workload, and the figure-row emitters shared by
//! the `rust/benches/fig*.rs` targets.

pub mod figures;
pub mod paper;
pub mod timing;
pub mod workload;

pub use figures::{FigureRow, Table};
pub use timing::{measure, MeasureOpts};
pub use workload::{sine_field, verify_roundtrip};
