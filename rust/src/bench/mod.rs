//! Benchmark harness (criterion is unavailable offline): robust timing,
//! the paper's `test_sine` workload, and the figure-row emitters shared by
//! the `rust/benches/fig*.rs` targets.

pub mod figures;
pub mod paper;
pub mod smoke;
pub mod timing;
pub mod workload;

pub use figures::{FigureRow, Table};
pub use smoke::{emit_json, quick_mode};
pub use timing::{measure, MeasureOpts};
pub use workload::{sine_field, verify_roundtrip};
