//! Figure-row emitters: the benches print the same rows/series the paper's
//! figures plot, in aligned text tables (one table per figure).

/// One data point of a figure series.
#[derive(Debug, Clone)]
pub struct FigureRow {
    /// Series label ("alltoall", "alltoallv", "comm", "fit", "measured"...).
    pub series: String,
    /// X value (core count, grid size, aspect ratio label...).
    pub x: String,
    /// Named columns (time_s, tflops, ...), printed in insertion order.
    pub cols: Vec<(String, f64)>,
}

impl FigureRow {
    pub fn new(series: impl Into<String>, x: impl Into<String>) -> Self {
        FigureRow { series: series.into(), x: x.into(), cols: Vec::new() }
    }

    pub fn col(mut self, name: impl Into<String>, v: f64) -> Self {
        self.cols.push((name.into(), v));
        self
    }
}

/// Text table builder for figure output.
#[derive(Debug, Default)]
pub struct Table {
    pub title: String,
    pub rows: Vec<FigureRow>,
}

impl Table {
    pub fn new(title: impl Into<String>) -> Self {
        Table { title: title.into(), rows: Vec::new() }
    }

    pub fn push(&mut self, row: FigureRow) {
        self.rows.push(row);
    }

    /// Render with aligned columns. Column set is the union over rows.
    pub fn render(&self) -> String {
        let mut col_names: Vec<String> = Vec::new();
        for r in &self.rows {
            for (name, _) in &r.cols {
                if !col_names.iter().any(|c| c == name) {
                    col_names.push(name.clone());
                }
            }
        }
        let mut header = vec!["series".to_string(), "x".to_string()];
        header.extend(col_names.iter().cloned());
        let mut body: Vec<Vec<String>> = Vec::new();
        for r in &self.rows {
            let mut line = vec![r.series.clone(), r.x.clone()];
            for name in &col_names {
                let v = r.cols.iter().find(|(n, _)| n == name).map(|(_, v)| *v);
                line.push(match v {
                    Some(v) if v.abs() >= 1e4 || (v != 0.0 && v.abs() < 1e-3) => {
                        format!("{v:.4e}")
                    }
                    Some(v) => format!("{v:.6}"),
                    None => "-".to_string(),
                });
            }
            body.push(line);
        }
        let ncols = header.len();
        let mut widths = vec![0usize; ncols];
        for (i, h) in header.iter().enumerate() {
            widths[i] = h.len();
        }
        for line in &body {
            for (i, cell) in line.iter().enumerate() {
                widths[i] = widths[i].max(cell.len());
            }
        }
        let fmt_line = |cells: &[String]| {
            cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:<w$}", c, w = widths[i]))
                .collect::<Vec<_>>()
                .join("  ")
        };
        let mut out = String::new();
        out.push_str(&format!("== {} ==\n", self.title));
        out.push_str(&fmt_line(&header));
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (ncols - 1)));
        out.push('\n');
        for line in &body {
            out.push_str(&fmt_line(line));
            out.push('\n');
        }
        out
    }

    /// Serialize as one JSON object: `{"bench": ..., "title": ...,
    /// "rows": [{"series": ..., "x": ..., "cols": {...}}]}`. Non-finite
    /// column values become `null` (JSON has no NaN/inf).
    pub fn to_json(&self, bench: &str) -> String {
        let mut out = String::new();
        out.push_str("{\"bench\":");
        out.push_str(&json_str(bench));
        out.push_str(",\"title\":");
        out.push_str(&json_str(&self.title));
        out.push_str(",\"rows\":[");
        for (i, r) in self.rows.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            out.push_str("{\"series\":");
            out.push_str(&json_str(&r.series));
            out.push_str(",\"x\":");
            out.push_str(&json_str(&r.x));
            out.push_str(",\"cols\":{");
            for (j, (name, v)) in r.cols.iter().enumerate() {
                if j > 0 {
                    out.push(',');
                }
                out.push_str(&json_str(name));
                out.push(':');
                if v.is_finite() {
                    out.push_str(&format!("{v:e}"));
                } else {
                    out.push_str("null");
                }
            }
            out.push_str("}}");
        }
        out.push_str("]}");
        out
    }
}

/// Minimal JSON string escaping (quotes, backslashes, control chars).
fn json_str(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned_table_with_union_columns() {
        let mut t = Table::new("Fig X");
        t.push(FigureRow::new("a2a", "1024").col("time_s", 1.5).col("tflops", 0.2));
        t.push(FigureRow::new("a2av", "1024").col("time_s", 2.5));
        let s = t.render();
        assert!(s.contains("== Fig X =="));
        assert!(s.contains("time_s"));
        assert!(s.contains("tflops"));
        assert!(s.contains("1.500000"));
        // Missing cell rendered as '-'.
        assert!(s.lines().last().unwrap().contains('-'));
    }

    #[test]
    fn to_json_escapes_and_serialises() {
        let mut t = Table::new("Fig \"X\"");
        t.push(FigureRow::new("measured", "2x4").col("pair_s", 1.5).col("bad", f64::NAN));
        let j = t.to_json("fig03");
        assert!(j.starts_with("{\"bench\":\"fig03\""), "{j}");
        assert!(j.contains("\\\"X\\\""), "{j}");
        assert!(j.contains("\"pair_s\":1.5e0"), "{j}");
        assert!(j.contains("\"bad\":null"), "{j}");
        assert!(j.ends_with("]}"), "{j}");
    }

    #[test]
    fn scientific_notation_for_extremes() {
        let mut t = Table::new("t");
        t.push(FigureRow::new("s", "x").col("big", 123456.0).col("small", 0.00001));
        let s = t.render();
        assert!(s.contains("1.2346e5") || s.contains("1.2346e+05") || s.contains("1.2346e+5"),
            "{s}");
    }
}
