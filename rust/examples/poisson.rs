//! Pseudospectral Poisson solver — the convolution/differentiation class
//! of applications §3.2 says the forward→backward design is made for.
//!
//! Solves ∇²u = f on [0, 2π)³ with f chosen so the exact solution is
//! u* = sin(x)·sin(y)·sin(z): transform f, divide by -|k|², transform
//! back, compare to u*. Exercises the full R2C → spectral algebra on
//! Z-pencils → C2R path, including the wavenumber bookkeeping of the
//! packed (Nx/2+1) layout.
//!
//! Run: `cargo run --release --example poisson`

use p3dfft::coordinator::{run_on_threads, PlanSpec};
use p3dfft::grid::ProcGrid;

/// Signed wavenumber for index `i` of an axis of length `n`.
fn wavenumber(i: usize, n: usize) -> f64 {
    if i <= n / 2 {
        i as f64
    } else {
        i as f64 - n as f64
    }
}

fn main() -> anyhow::Result<()> {
    let n = 48usize;
    let spec = PlanSpec::new([n, n, n], ProcGrid::new(2, 2))?;
    println!("poisson: -∇²u = -f, {n}^3 grid on 2x2 ranks (pseudospectral)");

    let report = run_on_threads(&spec, move |ctx| {
        let two_pi = 2.0 * std::f64::consts::PI;
        let hx = two_pi / n as f64;
        // f = ∇²u* = -3 sin(x) sin(y) sin(z).
        let f = ctx.make_real_input(|x, y, z| {
            -3.0 * (x as f64 * hx).sin() * (y as f64 * hx).sin() * (z as f64 * hx).sin()
        });
        let mut fhat = ctx.alloc_output();
        ctx.forward(&f, &mut fhat)?;

        // û(k) = f̂(k) / -(kx² + ky² + kz²);  û(0) = 0 (gauge).
        let zp = ctx.plan.decomp.z_pencil(ctx.rank());
        for xl in 0..zp.dims[0] {
            let kx = wavenumber(xl + zp.offsets[0], n); // packed axis: kx >= 0
            for yl in 0..zp.dims[1] {
                let ky = wavenumber(yl + zp.offsets[1], n);
                for z in 0..zp.dims[2] {
                    let kz = wavenumber(z, n);
                    let k2 = kx * kx + ky * ky + kz * kz;
                    let idx = (xl * zp.dims[1] + yl) * zp.dims[2] + z;
                    if k2 == 0.0 {
                        fhat[idx] = p3dfft::Complex::zero();
                    } else {
                        fhat[idx] = fhat[idx].scale(-1.0 / k2);
                    }
                }
            }
        }

        let mut u = ctx.alloc_input();
        ctx.backward(&fhat, &mut u)?;
        let norm = ctx.plan.normalization();

        // Compare to the exact solution.
        let exact = ctx.make_real_input(|x, y, z| {
            (x as f64 * hx).sin() * (y as f64 * hx).sin() * (z as f64 * hx).sin()
        });
        let mut max_err = 0.0f64;
        for (g, e) in u.iter().zip(&exact) {
            max_err = max_err.max((g / norm - e).abs());
        }
        Ok(ctx.max_over_ranks(max_err))
    })?;

    let err = report.per_rank[0];
    println!("max |u - u*| = {err:.3e}");
    println!("stage totals: {}", report.stage_summary());
    anyhow::ensure!(err < 1e-10, "Poisson solve inaccurate");
    println!("poisson OK — spectral solve matches the analytic solution");
    Ok(())
}
