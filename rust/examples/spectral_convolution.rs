//! Dealiased spectral convolution — the "convolution and differentiation
//! algorithms that require forward and backward transforms in sequence"
//! §3.2 designs the no-transpose-back API around.
//!
//! Computes the product h = f·g pseudospectrally with 2/3-rule dealiasing:
//! forward(f), forward(g) → truncate modes |k| > N/3 → pointwise product
//! theorem check — here we instead verify the convolution theorem itself:
//! FFT(f·g) == (FFT(f) ⊛ FFT(g)) / N³ on a small grid, using the
//! distributed pipeline for all three transforms and a naive spectral
//! convolution as the oracle on rank 0.
//!
//! Run: `cargo run --release --example spectral_convolution`

use p3dfft::coordinator::{run_on_threads, PlanSpec};
use p3dfft::fft::Complex;
use p3dfft::grid::ProcGrid;

fn main() -> anyhow::Result<()> {
    let n = 12usize; // small: the oracle convolution is O(N^6)
    let spec = PlanSpec::new([n, n, n], ProcGrid::new(2, 2))?;
    println!("spectral_convolution: verifying the convolution theorem on {n}^3, 2x2 ranks");

    let report = run_on_threads(&spec, move |ctx| {
        let two_pi = 2.0 * std::f64::consts::PI;
        let f = ctx.make_real_input(|x, y, _z| {
            (two_pi * x as f64 / n as f64).sin() + 0.5 * (two_pi * y as f64 / n as f64).cos()
        });
        let g = ctx.make_real_input(|x, _y, z| {
            (two_pi * 2.0 * x as f64 / n as f64).cos() + 0.3 * (two_pi * z as f64 / n as f64).sin()
        });
        let h: Vec<f64> = f.iter().zip(&g).map(|(a, b)| a * b).collect();

        let mut fhat = ctx.alloc_output();
        let mut ghat = ctx.alloc_output();
        let mut hhat = ctx.alloc_output();
        ctx.forward(&f, &mut fhat)?;
        ctx.forward(&g, &mut ghat)?;
        ctx.forward(&h, &mut hhat)?;

        // Gather full spectra on rank 0 via the world communicator.
        let zp = ctx.plan.decomp.z_pencil(ctx.rank());
        let pack = |v: &[Complex<f64>]| -> Vec<f64> {
            let mut out = Vec::with_capacity(v.len() * 2 + 6);
            out.push(zp.dims[0] as f64);
            out.push(zp.dims[1] as f64);
            out.push(zp.dims[2] as f64);
            out.push(zp.offsets[0] as f64);
            out.push(zp.offsets[1] as f64);
            out.push(zp.offsets[2] as f64);
            for c in v {
                out.push(c.re);
                out.push(c.im);
            }
            out
        };
        let fall = ctx.world.gatherv(&pack(&fhat), 0);
        let gall = ctx.world.gatherv(&pack(&ghat), 0);
        let hall = ctx.world.gatherv(&pack(&hhat), 0);

        if ctx.rank() != 0 {
            return Ok(0.0);
        }
        // Assemble [kx][ky][kz] full grids (packed h axis).
        let hx = n / 2 + 1;
        let assemble = |parts: Vec<Vec<f64>>| -> Vec<Complex<f64>> {
            let mut g = vec![Complex::<f64>::zero(); hx * n * n];
            for part in parts {
                let (d0, d1, d2) = (part[0] as usize, part[1] as usize, part[2] as usize);
                let (o0, o1, _o2) = (part[3] as usize, part[4] as usize, part[5] as usize);
                for a in 0..d0 {
                    for b in 0..d1 {
                        for c in 0..d2 {
                            let idx = 6 + 2 * ((a * d1 + b) * d2 + c);
                            g[((a + o0) * n + (b + o1)) * n + c] =
                                Complex::new(part[idx], part[idx + 1]);
                        }
                    }
                }
            }
            g
        };
        let fg = assemble(fall.expect("root"));
        let gg = assemble(gall.expect("root"));
        let hg = assemble(hall.expect("root"));

        // Reconstruct full (unpacked) spectra using conjugate symmetry,
        // then convolve: H[k] = (1/N^3) sum_q F[q] G[k-q  mod N].
        let full = |g: &Vec<Complex<f64>>| -> Vec<Complex<f64>> {
            let mut out = vec![Complex::<f64>::zero(); n * n * n];
            for kx in 0..n {
                for ky in 0..n {
                    for kz in 0..n {
                        let v = if kx < hx {
                            g[(kx * n + ky) * n + kz]
                        } else {
                            // F(-k) = conj(F(k))
                            let cx = (n - kx) % n;
                            let cy = (n - ky) % n;
                            let cz = (n - kz) % n;
                            g[(cx * n + cy) * n + cz].conj()
                        };
                        out[(kx * n + ky) * n + kz] = v;
                    }
                }
            }
            out
        };
        let ff = full(&fg);
        let gf = full(&gg);
        let norm = (n * n * n) as f64;
        let mut max_err = 0.0f64;
        // Check a subset of modes (full check is O(N^6); 27 modes suffice).
        for &kx in &[0usize, 1, 3] {
            for &ky in &[0usize, 2, 5] {
                for &kz in &[0usize, 1, 4] {
                    let mut acc = Complex::<f64>::zero();
                    for qx in 0..n {
                        for qy in 0..n {
                            for qz in 0..n {
                                let f1 = ff[(qx * n + qy) * n + qz];
                                let g1 = gf
                                    [(((kx + n - qx) % n) * n + ((ky + n - qy) % n)) * n
                                        + ((kz + n - qz) % n)];
                                acc += f1 * g1;
                            }
                        }
                    }
                    let expect = acc.scale(1.0 / norm);
                    let got = hg[(kx * n + ky) * n + kz];
                    max_err = max_err.max((got - expect).abs());
                }
            }
        }
        Ok(max_err)
    })?;

    let err = report.per_rank[0];
    println!("max |FFT(f*g) - conv(FFT f, FFT g)/N^3| over sampled modes = {err:.3e}");
    anyhow::ensure!(err < 1e-9, "convolution theorem violated");
    println!("spectral_convolution OK — distributed transforms satisfy the convolution theorem");
    Ok(())
}
