//! Dealiased spectral convolution — the "convolution and differentiation
//! algorithms that require forward and backward transforms in sequence"
//! §3.2 designs the no-transpose-back API around.
//!
//! Uses the *fused* convolution entry point: `ctx.convolve(&f, &g, &mut h)`
//! runs forward(f) and forward(g) through shared pair-transposes, forms
//! the pointwise product in Z-pencils without leaving them, and transforms
//! back — four transpose stages where the unfused
//! forward+forward+product+backward sequence runs six. Verified two ways:
//!
//! * real space: `h / N³` equals the naive circular convolution
//!   `c[x] = Σ_y f[y]·g[x−y mod N]` at sampled points (O(N³) per point);
//! * spectral space: `FFT(h / N³) == FFT(f) ⊙ FFT(g)` on every retained
//!   mode, using the shared rank-0 spectrum assembly from
//!   [`p3dfft::util::spectrum`].
//!
//! Run: `cargo run --release --example spectral_convolution`

use p3dfft::coordinator::{run_on_threads, Engine, PlanSpec, RankPlan};
use p3dfft::grid::ProcGrid;
use p3dfft::util::spectrum::gather_spectrum;

/// The two input fields as pure functions of global coordinates (each
/// rank fills its pencil from these; the oracle re-evaluates them).
fn f_field(n: usize) -> impl Fn(usize, usize, usize) -> f64 {
    let two_pi = 2.0 * std::f64::consts::PI;
    move |x, y, _z| {
        (two_pi * x as f64 / n as f64).sin() + 0.5 * (two_pi * y as f64 / n as f64).cos()
    }
}

fn g_field(n: usize) -> impl Fn(usize, usize, usize) -> f64 {
    let two_pi = 2.0 * std::f64::consts::PI;
    move |x, _y, z| {
        (two_pi * 2.0 * x as f64 / n as f64).cos() + 0.3 * (two_pi * z as f64 / n as f64).sin()
    }
}

fn main() -> anyhow::Result<()> {
    let n = 12usize; // small: the circular-convolution oracle is O(N^3) per point
    let spec = PlanSpec::new([n, n, n], ProcGrid::new(2, 2))?;
    println!("spectral_convolution: fused convolve on {n}^3, 2x2 ranks");

    // The fused chain must save exactly two transpose stages over the
    // unfused forward + forward + product + backward sequence.
    let probe = RankPlan::<f64>::new(&spec, 0, Engine::Native)?;
    let transposes = |d: &str| {
        d.split(" -> ").filter(|s| s.starts_with("xy-") || s.starts_with("yz-")).count()
    };
    let fused = transposes(&probe.describe_convolve()?);
    let unfused =
        2 * transposes(&probe.describe_forward()) + transposes(&probe.describe_backward());
    println!("transpose stages: fused convolve {fused} vs unfused {unfused}");
    anyhow::ensure!(fused + 2 == unfused, "fused chain must skip two interior transposes");

    let report = run_on_threads(&spec, move |ctx| {
        let f = ctx.make_real_input(f_field(n));
        let g = ctx.make_real_input(g_field(n));

        let mut h = ctx.alloc_input();
        ctx.convolve(&f, &g, &mut h)?;
        let norm = ctx.plan.normalization();
        // h / N^3 is the circular convolution of f and g.
        let c: Vec<f64> = h.iter().map(|v| v / norm).collect();

        // Real-space oracle at a few local points per rank.
        let xp = ctx.plan.decomp.x_pencil(ctx.rank());
        let (nyl, nx) = (xp.dims[1], xp.dims[2]);
        let (ff, gf) = (f_field(n), g_field(n));
        let mut max_err = 0.0f64;
        for &(xl, yl, zl) in &[(0usize, 0usize, 0usize), (1, 2, 1), (3, 1, 2), (n - 1, 0, 1)] {
            let (gx, gy, gz) = (xl, yl + xp.offsets[1], zl + xp.offsets[0]);
            let mut acc = 0.0f64;
            for qz in 0..n {
                for qy in 0..n {
                    for qx in 0..n {
                        acc += ff(qx, qy, qz)
                            * gf((gx + n - qx) % n, (gy + n - qy) % n, (gz + n - qz) % n);
                    }
                }
            }
            let got = c[(zl * nyl + yl) * nx + xl];
            max_err = max_err.max((got - acc).abs());
        }
        let real_err = ctx.max_over_ranks(max_err);

        // Spectral oracle: FFT(c) must equal FFT(f) ⊙ FFT(g) everywhere.
        let mut fhat = ctx.alloc_output();
        let mut ghat = ctx.alloc_output();
        let mut chat = ctx.alloc_output();
        ctx.forward(&f, &mut fhat)?;
        ctx.forward(&g, &mut ghat)?;
        ctx.forward(&c, &mut chat)?;
        let d = &ctx.plan.decomp;
        let (fall, gall, call) = (
            gather_spectrum(&ctx.world, d, &fhat),
            gather_spectrum(&ctx.world, d, &ghat),
            gather_spectrum(&ctx.world, d, &chat),
        );
        let spectral_err = match (fall, gall, call) {
            (Some(fg), Some(gg), Some(cg)) => {
                let mut err = 0.0f64;
                let mut mag = 0.0f64;
                for ((&a, &b), &c) in fg.iter().zip(&gg).zip(&cg) {
                    err = err.max((c - a * b).abs());
                    mag = mag.max((a * b).abs());
                }
                err / mag.max(1.0)
            }
            _ => 0.0, // non-root ranks
        };
        Ok((real_err, spectral_err))
    })?;

    let (real_err, spectral_err) = report.per_rank[0];
    println!("max |h/N^3 - circular_conv(f, g)| over sampled points = {real_err:.3e}");
    println!("max relative |FFT(h/N^3) - FFT(f) . FFT(g)| over all modes = {spectral_err:.3e}");
    anyhow::ensure!(real_err < 1e-8, "real-space convolution oracle violated");
    anyhow::ensure!(spectral_err < 1e-12, "convolution theorem violated");
    println!(
        "spectral_convolution OK — fused convolve matches the naive oracle \
         with {fused} transpose stages instead of {unfused}"
    );
    Ok(())
}
