//! End-to-end driver: proves all three layers compose and reproduces the
//! paper's headline experiment protocol on this host.
//!
//! 1. **Three-layer path**: runs the distributed transform with the PJRT
//!    engine — Rust coordinator → AOT HLO artifacts (JAX/Pallas matmul-DFT
//!    kernels) → PJRT CPU — and cross-checks the spectrum against the
//!    native engine, bit-for-bit-level tolerances.
//! 2. **Measured scaling**: `test_sine` pairs at P = 1, 2, 4 thread-ranks
//!    (strong scaling at laptop scale) with per-stage breakdown.
//! 3. **Calibrated model**: measures this host's FFT flop rate and pack
//!    bandwidth, then regenerates the paper's weak-scaling experiment
//!    (Fig. 9: 512³/16 → 8192³/65536 on the Cray XT5 model) and reports
//!    the efficiency number the paper quotes as 45%.
//!
//! Run: `cargo run --release --example e2e_scaling_study`
//! (Uses `artifacts/`; falls back to native-only with a warning if absent.)

use p3dfft::bench::{sine_field, verify_roundtrip, FigureRow, Table};
use p3dfft::coordinator::{run_on_threads, EngineKind, PlanSpec};
use p3dfft::grid::ProcGrid;
use p3dfft::netmodel::model::weak_efficiency;
use p3dfft::netmodel::{predict, Calibration, Machine, ModelInput};

fn main() -> anyhow::Result<()> {
    println!("=== e2e scaling study ===\n");

    // ---- 1. Three-layer path (PJRT engine) -------------------------------
    let artifacts = std::path::Path::new("artifacts");
    if artifacts.join("manifest.txt").exists() {
        let dims = [32, 32, 32];
        let spec_pjrt = PlanSpec::new(dims, ProcGrid::new(2, 2))?
            .with_engine(EngineKind::Pjrt { artifacts_dir: artifacts.to_path_buf() });
        let t0 = std::time::Instant::now();
        let report = run_on_threads(&spec_pjrt, move |ctx| {
            let input = ctx.make_real_input(sine_field::<f64>(32, 32, 32));
            let mut out = ctx.alloc_output();
            let mut back = ctx.alloc_input();
            ctx.forward(&input, &mut out)?;
            ctx.backward(&out, &mut back)?;
            Ok(verify_roundtrip(&input, &back, ctx.plan.normalization()))
        })?;
        let err = report.per_rank.iter().cloned().fold(0.0f64, f64::max);
        println!(
            "[1] PJRT three-layer path: 32^3 on 2x2 ranks via AOT JAX/Pallas artifacts"
        );
        println!("    roundtrip error {err:.3e}  (wall {:.2}s incl. XLA compile)", t0.elapsed().as_secs_f64());
        anyhow::ensure!(err < 1e-8, "PJRT roundtrip failed");
        println!("    OK — Rust → PJRT → Pallas matmul-DFT kernels agree with native\n");
    } else {
        println!("[1] SKIPPED PJRT path: no artifacts/ (run `make artifacts`)\n");
    }

    // ---- 2. Measured strong scaling at laptop scale -----------------------
    println!("[2] measured strong scaling, test_sine 64^3 (threads on this host)");
    let mut table = Table::new("measured: 64^3 fwd+bwd pair vs P");
    let mut measured: Vec<(usize, f64)> = Vec::new();
    for (m1, m2) in [(1, 1), (1, 2), (2, 2), (2, 4)] {
        let p = m1 * m2;
        let spec = PlanSpec::new([64, 64, 64], ProcGrid::new(m1, m2))?;
        let report = run_on_threads(&spec, move |ctx| {
            let input = ctx.make_real_input(sine_field::<f64>(64, 64, 64));
            let mut out = ctx.alloc_output();
            let mut back = ctx.alloc_input();
            // Warmup + 3 timed iterations.
            ctx.forward(&input, &mut out)?;
            ctx.backward(&out, &mut back)?;
            let t0 = std::time::Instant::now();
            for _ in 0..3 {
                ctx.forward(&input, &mut out)?;
                ctx.backward(&out, &mut back)?;
            }
            Ok(ctx.max_over_ranks(t0.elapsed().as_secs_f64() / 3.0))
        })?;
        let pair = report.per_rank[0];
        measured.push((p, pair));
        table.push(
            FigureRow::new("measured", format!("{p} ({m1}x{m2})"))
                .col("pair_s", pair)
                .col("comm_s", report.comm())
                .col("compute_s", report.compute()),
        );
    }
    print!("{}", table.render());
    println!();

    // ---- 3. Calibrated model: the paper's weak-scaling protocol ----------
    println!("[3] calibrating model from this host's own kernels...");
    let cal = Calibration::measure();
    println!(
        "    measured: FFT {:.2} Gflop/s, pack {:.2} GB/s",
        cal.fft_flops / 1e9,
        cal.pack_bw / 1e9
    );

    println!("\n    Fig. 9 protocol on the Cray XT5 machine model:");
    let machine = Machine::cray_xt5();
    let series: [(usize, usize); 5] =
        [(512, 16), (1024, 128), (2048, 1024), (4096, 8192), (8192, 65536)];
    let mut fig9 = Table::new("model: weak scaling (Fig. 9)");
    let mut times = Vec::new();
    for &(n, p) in &series {
        let m1 = machine.cores_per_node.min(p);
        let input = ModelInput::cubic(n, m1, p / m1, machine.clone());
        let pair = 2.0 * predict(&input).total();
        times.push((n, p, pair));
        fig9.push(
            FigureRow::new("model", format!("{n}^3 @ {p}"))
                .col("pair_s", pair)
                .col("comm_share", predict(&input).comm() / predict(&input).total()),
        );
    }
    print!("{}", fig9.render());

    let (n1, p1, t1) = times[1]; // 1024^3 @ 128, the paper's 128-core anchor
    let (n2, p2, t2) = times[4]; // 8192^3 @ 65536
    let eff = weak_efficiency(n1, p1, t1, n2, p2, t2);
    println!(
        "\n    weak-scaling efficiency 128 -> 65536 cores: {:.1}% (paper: 45%)",
        100.0 * eff
    );
    anyhow::ensure!(
        eff > 0.25 && eff < 0.75,
        "weak-scaling efficiency {eff} far outside the paper's band"
    );
    println!("\ne2e_scaling_study OK");
    Ok(())
}
