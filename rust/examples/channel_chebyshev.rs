//! Wall-bounded channel setup — Fourier × Fourier × Chebyshev, the
//! "one dimension of non-homogeneity" configuration of §2 (periodic x, y;
//! rigid walls in z).
//!
//! Transforms a field that is polynomial in the wall-normal coordinate,
//! differentiates it spectrally with the Chebyshev recurrence on the
//! Z-pencil coefficients, transforms back, and compares with the analytic
//! derivative. Exercises `TransformKind::Cheby` end to end.
//!
//! Run: `cargo run --release --example channel_chebyshev`

use p3dfft::coordinator::{run_on_threads, PlanSpec, TransformKind};
use p3dfft::grid::ProcGrid;

fn main() -> anyhow::Result<()> {
    let (nx, ny, nz) = (16usize, 16usize, 17usize);
    let spec =
        PlanSpec::new([nx, ny, nz], ProcGrid::new(2, 2))?.with_third(TransformKind::Cheby);
    println!(
        "channel_chebyshev: {nx}x{ny}x{nz} (Fourier x Fourier x Chebyshev), 2x2 ranks"
    );

    let report = run_on_threads(&spec, move |ctx| {
        let two_pi = 2.0 * std::f64::consts::PI;
        // Gauss-Lobatto wall-normal coordinate ζ_j = cos(π j / (Nz-1)).
        let zeta = |j: usize| (std::f64::consts::PI * j as f64 / (nz - 1) as f64).cos();
        // u(x, y, ζ) = sin(2πx/Nx) · (ζ³ - ζ); du/dζ = 3ζ² - 1.
        let u = ctx.make_real_input(|x, y, z| {
            let _ = y;
            let zt = zeta(z);
            (two_pi * x as f64 / nx as f64).sin() * (zt * zt * zt - zt)
        });

        let mut coef = ctx.alloc_output();
        ctx.forward(&u, &mut coef)?;

        // Chebyshev derivative recurrence on each Z line of coefficients.
        // Our DCT-I output relates to Chebyshev coefficients by
        // a_k = y_k / (Nz-1), with a_0 and a_{Nz-1} halved; the recurrence
        // b_{k} = b_{k+2} + 2(k+1) a_{k+1} (b half-coefficients like a)
        // produces derivative coefficients in the same convention, so we
        // can apply it directly to the raw DCT values with the matching
        // endpoint handling.
        let zp = ctx.plan.decomp.z_pencil(ctx.rank());
        let m = nz;
        let mut a = vec![p3dfft::Complex::<f64>::zero(); m];
        for line in coef.chunks_exact_mut(m) {
            // Convert to true Chebyshev coefficients.
            let s = 1.0 / (m as f64 - 1.0);
            for (k, c) in line.iter().enumerate() {
                a[k] = c.scale(s);
            }
            a[0] = a[0].scale(0.5);
            a[m - 1] = a[m - 1].scale(0.5);
            // b_k: derivative coefficients (true convention).
            let mut b = vec![p3dfft::Complex::<f64>::zero(); m + 2];
            for k in (0..m - 1).rev() {
                b[k] = b[k + 2] + a[k + 1].scale(2.0 * (k + 1) as f64);
            }
            b[0] = b[0].scale(0.5);
            // Back to DCT-I raw convention for the inverse transform:
            // y_k = b_k * (Nz-1), endpoints doubled.
            for k in 0..m {
                let mut v = b[k].scale(m as f64 - 1.0);
                if k == 0 || k == m - 1 {
                    v = v.scale(2.0);
                }
                line[k] = v;
            }
        }

        let mut dudz = ctx.alloc_input();
        ctx.backward(&coef, &mut dudz)?;
        let norm = ctx.plan.normalization();

        let exact = ctx.make_real_input(|x, _y, z| {
            let zt = zeta(z);
            (two_pi * x as f64 / nx as f64).sin() * (3.0 * zt * zt - 1.0)
        });
        let mut max_err = 0.0f64;
        for (g, e) in dudz.iter().zip(&exact) {
            max_err = max_err.max((g / norm - e).abs());
        }
        let _ = zp;
        Ok(ctx.max_over_ranks(max_err))
    })?;

    let err = report.per_rank[0];
    println!("max |du/dζ - exact| = {err:.3e}");
    anyhow::ensure!(err < 1e-9, "Chebyshev derivative inaccurate");
    println!("channel_chebyshev OK — spectral wall-normal derivative is exact");
    Ok(())
}
