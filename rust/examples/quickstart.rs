//! Quickstart: the paper's `test_sine` protocol on a small grid.
//!
//! Initialises a 3D sine field decomposed as X-pencils over a 2x2
//! processor grid (4 rank threads), runs `iterations` forward+backward
//! pairs, verifies the roundtrip against the known normalisation, and
//! prints the per-stage timing breakdown — the same trace as Fig. 2.
//!
//! Run: `cargo run --release --example quickstart`

use p3dfft::bench::{sine_field, verify_roundtrip};
use p3dfft::coordinator::{run_on_threads, PlanSpec};
use p3dfft::grid::ProcGrid;

fn main() -> anyhow::Result<()> {
    let dims = [64, 64, 64];
    let pgrid = ProcGrid::new(2, 2);
    let iterations = 3;
    let spec = PlanSpec::new(dims, pgrid)?;
    println!(
        "quickstart: {}x{}x{} grid, {}x{} processor grid ({} ranks), {} iterations",
        dims[0], dims[1], dims[2], pgrid.m1, pgrid.m2, spec.p(), iterations
    );
    println!(
        "pipeline: R2C over X | ROW transpose | C2C over Y | COLUMN transpose | C2C over Z"
    );

    let (nx, ny, nz) = (dims[0], dims[1], dims[2]);
    let report = run_on_threads(&spec, move |ctx| {
        let input = ctx.make_real_input(sine_field::<f64>(nx, ny, nz));
        let mut spectrum = ctx.alloc_output();
        let mut back = ctx.alloc_input();
        let t0 = std::time::Instant::now();
        let mut worst = 0.0f64;
        for _ in 0..iterations {
            ctx.forward(&input, &mut spectrum)?;
            ctx.backward(&spectrum, &mut back)?;
            worst = worst.max(verify_roundtrip(&input, &back, ctx.plan.normalization()));
        }
        let pair = t0.elapsed().as_secs_f64() / iterations as f64;
        Ok((ctx.max_over_ranks(pair), ctx.max_over_ranks(worst)))
    })?;

    let (pair_s, err) = report.per_rank[0];
    println!("\nfwd+bwd pair: {pair_s:.6} s (avg of {iterations})");
    println!("stage totals (max over ranks): {}", report.stage_summary());
    println!("fabric traffic: {:.2} MiB", report.bytes as f64 / (1024.0 * 1024.0));
    println!("max roundtrip error: {err:.3e}");
    anyhow::ensure!(err < 1e-10, "verification failed");
    println!("verification OK — data identical up to the 1/(Nx*Ny*Nz) scale factor");
    Ok(())
}
