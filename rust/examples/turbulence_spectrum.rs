//! Turbulence energy spectrum — the paper's motivating workload class
//! (pseudospectral DNS; Donzis/Yeung-style analyses).
//!
//! Builds the Taylor-Green vortex velocity field (u, v, w) and computes
//! the shell-summed kinetic-energy spectrum twice with the distributed
//! pipeline: once on the full grid, and once on a *truncated* plan
//! (`with_truncation(Spherical23)`, the 2/3 dealiasing rule) whose
//! exchanges ship only retained modes. Taylor-Green concentrates all
//! energy in the |k|² = 3 shell — well inside the retained sphere — so
//! the truncated spectrum must match the full-grid spectrum on every
//! shell while moving measurably fewer bytes through the transposes.
//!
//! Run: `cargo run --release --example turbulence_spectrum`

use p3dfft::coordinator::{run_on_threads, PlanSpec};
use p3dfft::grid::ProcGrid;
use p3dfft::util::spectrum::shell_energy;
use p3dfft::Truncation;

/// Forward-transform the Taylor-Green components on `spec`'s pipeline and
/// return the rank-reduced kinetic-energy spectrum `E(k)`.
fn spectrum_of(spec: &PlanSpec, n: usize) -> anyhow::Result<(Vec<f64>, u64)> {
    let report = run_on_threads(spec, move |ctx| {
        let h = 2.0 * std::f64::consts::PI / n as f64;
        // Taylor-Green: u = cos x sin y sin z, v = -sin x cos y sin z, w = 0.
        let fields: [Vec<f64>; 3] = [
            ctx.make_real_input(|x, y, z| {
                (x as f64 * h).cos() * (y as f64 * h).sin() * (z as f64 * h).sin()
            }),
            ctx.make_real_input(|x, y, z| {
                -(x as f64 * h).sin() * (y as f64 * h).cos() * (z as f64 * h).sin()
            }),
            ctx.make_real_input(|_, _, _| 0.0),
        ];
        let d = ctx.plan.decomp.clone();
        let mut shells = vec![0.0f64; n / 2 + 1];
        for f in &fields {
            let mut fhat = ctx.alloc_output();
            ctx.forward(f, &mut fhat)?;
            for (s, e) in shells.iter_mut().zip(shell_energy(&d, ctx.rank(), &fhat)) {
                *s += e;
            }
        }
        // Reduce shells across ranks.
        let reduced: Vec<f64> = shells.iter().map(|s| ctx.sum_over_ranks(*s)).collect();
        Ok(reduced)
    })?;
    Ok((report.per_rank[0].clone(), report.bytes))
}

fn main() -> anyhow::Result<()> {
    let n = 32usize;
    let full_spec = PlanSpec::new([n, n, n], ProcGrid::new(2, 2))?;
    let trunc_spec = full_spec.clone().with_truncation(Truncation::Spherical23);
    println!("turbulence_spectrum: Taylor-Green vortex on {n}^3, 2x2 ranks");

    let (full, full_bytes) = spectrum_of(&full_spec, n)?;
    let (trunc, trunc_bytes) = spectrum_of(&trunc_spec, n)?;

    println!("\n  k    E(k) full      E(k) spherical23");
    let mut total = 0.0;
    for (k, (f, t)) in full.iter().zip(&trunc).enumerate() {
        if *f > 1e-15 || *t > 1e-15 {
            println!("  {k:<4} {f:.6e}  {t:.6e}");
        }
        total += f;
    }
    println!("total kinetic energy (full grid): {total:.6}");
    println!(
        "exchange bytes: full {full_bytes}, truncated {trunc_bytes} \
         ({:.2}x less on the wire)",
        full_bytes as f64 / trunc_bytes.max(1) as f64
    );

    // Taylor-Green analytic checks: all energy in the |k| = sqrt(3) shell
    // (rounds to 2); total KE = (1/V)∫ ½(u²+v²) = 1/8.
    let expected_total = 0.125;
    anyhow::ensure!(
        (total - expected_total).abs() < 1e-10,
        "total KE {total} != {expected_total}"
    );
    anyhow::ensure!(
        (full[2] - expected_total).abs() < 1e-10,
        "energy not concentrated in the sqrt(3) shell"
    );
    // The energy-carrying modes are well inside the retained sphere, so
    // pruned exchanges must reproduce the spectrum shell for shell.
    for (k, (f, t)) in full.iter().zip(&trunc).enumerate() {
        anyhow::ensure!(
            (f - t).abs() < 1e-12,
            "truncated spectrum deviates on retained shell {k}: {f} vs {t}"
        );
    }
    anyhow::ensure!(
        trunc_bytes < full_bytes,
        "pruned exchanges must move fewer bytes ({trunc_bytes} !< {full_bytes})"
    );
    println!(
        "turbulence_spectrum OK — truncated plan reproduces E(k) on retained shells, \
         total = 1/8"
    );
    Ok(())
}
