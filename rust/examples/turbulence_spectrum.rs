//! Turbulence energy spectrum — the paper's motivating workload class
//! (pseudospectral DNS; Donzis/Yeung-style analyses).
//!
//! Builds the Taylor-Green vortex velocity field (u, v, w), forward-
//! transforms each component with the distributed pipeline, and
//! accumulates the shell-summed kinetic-energy spectrum
//! E(k) = ½ Σ_{|k'|∈shell k} |û|² + |v̂|² + |ŵ|², using conjugate-symmetry
//! weights for the packed kx axis. Taylor-Green concentrates all energy
//! in |k|² = 3 modes, giving an exact check.
//!
//! Run: `cargo run --release --example turbulence_spectrum`

use p3dfft::coordinator::{run_on_threads, PlanSpec};
use p3dfft::grid::ProcGrid;

fn wavenumber(i: usize, n: usize) -> f64 {
    if i <= n / 2 {
        i as f64
    } else {
        i as f64 - n as f64
    }
}

fn main() -> anyhow::Result<()> {
    let n = 32usize;
    let spec = PlanSpec::new([n, n, n], ProcGrid::new(2, 2))?;
    println!("turbulence_spectrum: Taylor-Green vortex on {n}^3, 2x2 ranks");

    let nshells = n / 2 + 1;
    let report = run_on_threads(&spec, move |ctx| {
        let h = 2.0 * std::f64::consts::PI / n as f64;
        // Taylor-Green: u = cos x sin y sin z, v = -sin x cos y sin z, w = 0.
        let fields: [Vec<f64>; 3] = [
            ctx.make_real_input(|x, y, z| {
                (x as f64 * h).cos() * (y as f64 * h).sin() * (z as f64 * h).sin()
            }),
            ctx.make_real_input(|x, y, z| {
                -(x as f64 * h).sin() * (y as f64 * h).cos() * (z as f64 * h).sin()
            }),
            ctx.make_real_input(|_, _, _| 0.0),
        ];
        let mut shells = vec![0.0f64; n / 2 + 1];
        let zp = ctx.plan.decomp.z_pencil(ctx.rank());
        let norm = (n as f64).powi(3);
        for f in &fields {
            let mut fhat = ctx.alloc_output();
            ctx.forward(f, &mut fhat)?;
            for xl in 0..zp.dims[0] {
                let kxi = xl + zp.offsets[0];
                let kx = wavenumber(kxi, n);
                let w = if kxi == 0 || (n % 2 == 0 && kxi == n / 2) { 1.0 } else { 2.0 };
                for yl in 0..zp.dims[1] {
                    let ky = wavenumber(yl + zp.offsets[1], n);
                    for z in 0..zp.dims[2] {
                        let kz = wavenumber(z, n);
                        let kmag = (kx * kx + ky * ky + kz * kz).sqrt();
                        let shell = kmag.round() as usize;
                        if shell < shells.len() {
                            let c = fhat[(xl * zp.dims[1] + yl) * zp.dims[2] + z];
                            shells[shell] += 0.5 * w * c.norm_sqr() / (norm * norm);
                        }
                    }
                }
            }
        }
        // Reduce shells across ranks.
        let mut reduced = vec![0.0f64; shells.len()];
        for (i, s) in shells.iter().enumerate() {
            reduced[i] = ctx.sum_over_ranks(*s);
        }
        Ok(reduced)
    })?;

    let spectrum = &report.per_rank[0];
    println!("\n  k    E(k)");
    let mut total = 0.0;
    for (k, e) in spectrum.iter().enumerate().take(nshells) {
        if *e > 1e-15 {
            println!("  {k:<4} {e:.6e}");
        }
        total += e;
    }
    println!("total kinetic energy: {total:.6}");

    // Taylor-Green analytic checks: all energy in the |k| = sqrt(3) shell
    // (rounds to 2); total KE = (1/V)∫ ½(u²+v²) = 1/8.
    let expected_total = 0.125;
    anyhow::ensure!(
        (total - expected_total).abs() < 1e-10,
        "total KE {total} != {expected_total}"
    );
    anyhow::ensure!(
        (spectrum[2] - expected_total).abs() < 1e-10,
        "energy not concentrated in the sqrt(3) shell"
    );
    println!("turbulence_spectrum OK — all energy in the |k|=√3 shell, total = 1/8");
    Ok(())
}
