//! Minimal offline stand-in for the `anyhow` crate, covering the API
//! surface this workspace's binaries and examples use: [`Result`],
//! [`Error`], [`anyhow!`], [`ensure!`] and [`bail!`].
//!
//! Semantics match real `anyhow` where it matters here: any
//! `std::error::Error + Send + Sync + 'static` converts into [`Error`]
//! via `?`, and `Error` renders its message for both `{}` and `{:#}`.

use std::fmt;

/// A type-erased error: the source error's rendered message (plus the
/// boxed source for `source()` chains).
pub struct Error {
    msg: String,
    source: Option<Box<dyn std::error::Error + Send + Sync + 'static>>,
}

impl Error {
    /// Construct from any displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Self {
        Error { msg: message.to_string(), source: None }
    }

    /// Construct from a concrete error value.
    pub fn new<E>(error: E) -> Self
    where
        E: std::error::Error + Send + Sync + 'static,
    {
        Error { msg: error.to_string(), source: Some(Box::new(error)) }
    }

    /// The underlying source error, if this `Error` wraps one.
    pub fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        self.source.as_deref().map(|e| e as &(dyn std::error::Error + 'static))
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(error: E) -> Self {
        Error::new(error)
    }
}

/// `anyhow::Result<T>` — `std::result::Result` specialised to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Build an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:literal, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

/// Return early with an error built from a format string.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error unless `cond` holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return Err($crate::Error::msg(concat!("condition failed: ", stringify!($cond))));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn needs_q() -> Result<()> {
        let io = std::io::Error::new(std::io::ErrorKind::NotFound, "gone");
        Err(io)?;
        Ok(())
    }

    #[test]
    fn question_mark_converts_std_errors() {
        let e = needs_q().unwrap_err();
        assert!(e.to_string().contains("gone"));
        assert!(e.source().is_some());
    }

    #[test]
    fn macros_build_messages() {
        let e = anyhow!("bad value {}", 7);
        assert_eq!(e.to_string(), "bad value 7");

        fn guard(x: i32) -> Result<i32> {
            ensure!(x > 0, "x must be positive, got {x}");
            if x > 100 {
                bail!("x too large: {x}");
            }
            Ok(x)
        }
        assert!(guard(5).is_ok());
        assert!(guard(-1).unwrap_err().to_string().contains("positive"));
        assert!(guard(200).unwrap_err().to_string().contains("too large"));
    }

    #[test]
    fn alternate_format_renders_message() {
        let e = Error::msg("top-level failure");
        assert_eq!(format!("{e:#}"), "top-level failure");
    }
}
