//! Copy-path ablation: mailbox vs single-copy (windowed) exchange.
//!
//! Measured: `test_sine` forward+backward pairs on thread ranks, mailbox
//! vs single-copy, under node maps {flat, 2 nodes} and overlap chunks
//! {1, 4}. Payloads are asserted bit-identical across every cell — the
//! copy discipline only changes how intra-node blocks travel (pack
//! straight into the receiver's registered window vs pack + mailbox
//! insert + extract). Asserted: on the flat map the windowed path copies
//! at most half the bytes the mailbox does (the blocking path's
//! theoretical reduction is 2.5x on size-2 sub-communicators), the wire
//! volume is identical, and wall-clock is no worse than the mailbox
//! within scheduler slack.
//!
//! `--quick` / `P3DFFT_BENCH_QUICK=1` shrinks the grid for the CI
//! bench-smoke job; `P3DFFT_BENCH_JSON=PATH` appends the table.

use p3dfft::bench::{emit_json, quick_mode, sine_field, verify_roundtrip, FigureRow, Table};
use p3dfft::coordinator::{run_on_threads, PlanSpec, RunReport};
use p3dfft::grid::ProcGrid;
use p3dfft::mpi::CopyMode;

fn run_cell(
    dims: [usize; 3],
    k: usize,
    cores: Option<usize>,
    copy: CopyMode,
    iterations: usize,
) -> (RunReport<(f64, f64, f64)>, f64, Vec<f64>) {
    let spec = PlanSpec::new(dims, ProcGrid::new(2, 2))
        .unwrap()
        .with_overlap_chunks(k)
        .unwrap()
        .with_cores_per_node(cores)
        .unwrap()
        .with_copy_path(Some(copy));
    let (nx, ny, nz) = (dims[0], dims[1], dims[2]);
    let report = run_on_threads(&spec, move |ctx| {
        let input = ctx.make_real_input(sine_field::<f64>(nx, ny, nz));
        let mut out = ctx.alloc_output();
        let mut back = ctx.alloc_input();
        // Warmup.
        ctx.forward(&input, &mut out)?;
        ctx.backward(&out, &mut back)?;
        ctx.state.timer.reset();
        // Best-of-N pair time: robust against scheduler noise, which is
        // what the cross-mode wall-clock assertion cares about.
        let mut best = f64::INFINITY;
        let mut worst = 0.0f64;
        for _ in 0..iterations {
            let t0 = std::time::Instant::now();
            ctx.forward(&input, &mut out)?;
            ctx.backward(&out, &mut back)?;
            best = best.min(t0.elapsed().as_secs_f64());
            worst = worst.max(verify_roundtrip(&input, &back, ctx.plan.normalization()));
        }
        // A payload digest to pin bit-identity across copy modes.
        let digest: f64 = out.iter().take(64).map(|c| c.re + c.im).sum();
        Ok((ctx.max_over_ranks(best), ctx.max_over_ranks(worst), digest))
    })
    .expect("copy bench run");
    let (pair_s, err, _) = report.per_rank[0];
    assert!(err < 1e-10, "roundtrip broke under {copy:?} k={k} cores={cores:?}: {err:.3e}");
    let digests: Vec<f64> = report.per_rank.iter().map(|r| r.2).collect();
    (report, pair_s, digests)
}

fn main() {
    let quick = quick_mode();
    let dims = if quick { [32, 32, 32] } else { [64, 64, 64] };
    let p = 4usize;
    let iterations = if quick { 3 } else { 5 };
    let ks: &[usize] = if quick { &[1, 4] } else { &[1, 2, 4] };
    let maps: &[(&str, Option<usize>)] = &[("flat", None), ("2node", Some(p / 2))];
    let mut table = Table::new(format!(
        "fig_copy: {}x{}x{} on 2x2 thread ranks, best of {iterations} pairs",
        dims[0], dims[1], dims[2]
    ));
    for &k in ks {
        for &(name, cores) in maps {
            let (mr, m_pair, m_digest) =
                run_cell(dims, k, cores, CopyMode::Mailbox, iterations);
            let (sr, s_pair, s_digest) =
                run_cell(dims, k, cores, CopyMode::SingleCopy, iterations);
            assert_eq!(
                m_digest, s_digest,
                "copy mode changed the spectrum at k={k} map={name}"
            );
            assert_eq!(
                mr.bytes, sr.bytes,
                "wire volume must be identical across copy modes (k={k} map={name})"
            );
            assert!(
                sr.copies_elided > 0,
                "windowed path elided nothing at k={k} map={name}"
            );
            if cores.is_none() {
                // Acceptance: on a flat fabric (every peer on-node) the
                // windowed path must at least halve the copied bytes.
                assert!(
                    2 * sr.bytes_copied <= mr.bytes_copied,
                    "k={k}: single-copy must copy <= half the mailbox's bytes \
                     ({} vs {})",
                    sr.bytes_copied,
                    mr.bytes_copied
                );
            }
            // Fewer copies must not cost wall-clock (generous slack: the
            // 4 ranks are threads sharing cores with the runner).
            assert!(
                s_pair <= m_pair * 1.25 + 5e-3,
                "k={k} map={name}: single-copy pair {s_pair:.6}s slower than \
                 mailbox {m_pair:.6}s beyond slack"
            );
            let reduction = mr.bytes_copied as f64 / sr.bytes_copied.max(1) as f64;
            table.push(
                FigureRow::new(format!("measured/{name}"), format!("k={k}"))
                    .col("mailbox_pair_s", m_pair)
                    .col("single_pair_s", s_pair)
                    .col("mailbox_copied_mib", mr.bytes_copied as f64 / (1024.0 * 1024.0))
                    .col("single_copied_mib", sr.bytes_copied as f64 / (1024.0 * 1024.0))
                    .col("copy_reduction", reduction)
                    .col("elided_mib", sr.copies_elided as f64 / (1024.0 * 1024.0)),
            );
        }
    }
    print!("{}", table.render());
    emit_json("fig_copy", &table);
    println!(
        "(copy_reduction = mailbox bytes_copied / single-copy bytes_copied; \
         payloads asserted bit-identical across modes and node maps)"
    );
}
