//! Calibration bench: `alltoall` vs `alltoallv` on the thread fabric,
//! across rank counts and message sizes — the measured side of the
//! USEEVEN story (§3.4). On this shared-memory fabric the two should be
//! close (no Cray pathology); the *model* injects the documented XT
//! penalty for the paper-scale rows of Fig. 4.

use p3dfft::bench::{measure, FigureRow, MeasureOpts, Table};
use p3dfft::mpi::Universe;

fn main() {
    let mut table = Table::new("calib: alltoall vs alltoallv (thread fabric)");
    for &p in &[2usize, 4, 8] {
        for &block in &[1024usize, 16384, 131072] {
            for use_v in [false, true] {
                let s = measure(MeasureOpts { warmup: 1, iterations: 5 }, || {
                    let u = Universe::new(p);
                    u.run(move |c| {
                        let send: Vec<f64> = vec![c.rank() as f64; block * p];
                        let mut recv = vec![0.0f64; block * p];
                        if use_v {
                            let counts = vec![block; p];
                            let displs: Vec<usize> =
                                (0..p).map(|j| j * block).collect();
                            c.alltoallv(&send, &counts, &displs, &mut recv, &counts, &displs);
                        } else {
                            c.alltoall(&send, &mut recv, block);
                        }
                        Ok(())
                    })
                    .unwrap();
                });
                let bytes = (p * (p - 1) * block * 8) as f64;
                table.push(
                    FigureRow::new(
                        if use_v { "alltoallv" } else { "alltoall" },
                        format!("P={p} blk={block}"),
                    )
                    .col("median_s", s.median)
                    .col("gbs", bytes / s.median / 1e9),
                );
            }
        }
    }
    print!("{}", table.render());
}
