//! Transform-as-a-service benchmark: plan-cache and request-coalescing
//! wins over the dedicated-plan baseline.
//!
//! Two figures:
//! * cold plan build vs cache-hit acquire — a hit is a lookup plus an
//!   `Arc` clone and must be >= 10x cheaper than compiling every rank's
//!   plan (asserted);
//! * coalesced widths {1, 4, 8} vs serial per-request dispatch — a
//!   width-8 group runs one rank universe, one tile pass and one
//!   exchange schedule per stage for all eight requests, and must be
//!   >= 2x the serial per-field throughput (asserted).
//!
//! The serve counters (cache hits/misses/evictions, coalesce-width
//! histogram, arena traffic, rank-0 pool bytes) ride along in the JSON
//! rows. `--quick` / `P3DFFT_BENCH_QUICK=1` shrinks the grid for the CI
//! bench-smoke job; `P3DFFT_BENCH_JSON=PATH` appends the tables.

use std::time::Instant;

use p3dfft::bench::{emit_json, quick_mode, FigureRow, Table};
use p3dfft::coordinator::PlanSpec;
use p3dfft::grid::ProcGrid;
use p3dfft::serve::{TransformService, MAX_COALESCE};

fn field(spec: &PlanSpec, seed: usize) -> Vec<f64> {
    let n = spec.nx * spec.ny * spec.nz;
    (0..n).map(|i| ((i * 31 + seed * 17 + 5) % 97) as f64 / 13.0 - 3.0).collect()
}

fn main() {
    let quick = quick_mode();
    let dims = if quick { [32, 32, 32] } else { [64, 64, 64] };
    let spec = PlanSpec::new(dims, ProcGrid::new(2, 2)).unwrap();
    let svc = TransformService::with_defaults();

    // ---- plan cache: cold build vs hit ------------------------------------
    let t0 = Instant::now();
    let cached = svc.acquire::<f64>(&spec).unwrap();
    let cold_s = t0.elapsed().as_secs_f64();
    let hit_iters = 200;
    let t0 = Instant::now();
    for _ in 0..hit_iters {
        svc.acquire::<f64>(&spec).unwrap();
    }
    let hit_s = t0.elapsed().as_secs_f64() / hit_iters as f64;
    let cache_ratio = cold_s / hit_s.max(1e-12);
    let pool_bytes = cached.plans[0].memory_report().total_bytes;
    let mut table = Table::new(format!(
        "fig_serve (plan cache): {}x{}x{} on 2x2, cold compile vs {hit_iters} hits",
        dims[0], dims[1], dims[2]
    ));
    table.push(FigureRow::new("cache", "cold").col("acquire_s", cold_s));
    table.push(
        FigureRow::new("cache", "hit")
            .col("acquire_s", hit_s)
            .col("speedup", cache_ratio)
            .col("rank0_pool_bytes", pool_bytes as f64),
    );
    print!("{}", table.render());
    emit_json("fig_serve", &table);
    assert!(
        cache_ratio >= 10.0,
        "cache hit must be >= 10x cheaper than a cold plan build \
         (cold {cold_s:.6}s vs hit {hit_s:.9}s = {cache_ratio:.1}x)"
    );

    // ---- request coalescing: widths {1, 4, 8} vs serial dispatch ----------
    let fields: Vec<Vec<f64>> = (0..MAX_COALESCE).map(|s| field(&spec, s)).collect();
    let refs: Vec<&[f64]> = fields.iter().map(|v| v.as_slice()).collect();
    // Warm the arena and pin correctness once before timing.
    let warm = svc.forward_batch(&spec, &refs).unwrap();
    let check = svc.forward(&spec, &fields[0]).unwrap();
    assert_eq!(warm[0], check, "coalesced output must match serial bit for bit");

    let reps = if quick { 2 } else { 5 };
    let t0 = Instant::now();
    for _ in 0..reps {
        for f in &fields {
            svc.forward(&spec, f).unwrap();
        }
    }
    let serial_per_field = t0.elapsed().as_secs_f64() / (reps * fields.len()) as f64;

    let mut table = Table::new(format!(
        "fig_serve (coalescing): {}x{}x{} on 2x2, {reps} reps, vs serial \
         {serial_per_field:.6}s/field",
        dims[0], dims[1], dims[2]
    ));
    let mut width8_per_field = f64::INFINITY;
    for w in [1usize, 4, 8] {
        let t0 = Instant::now();
        for _ in 0..reps {
            svc.forward_batch(&spec, &refs[..w]).unwrap();
        }
        let per_field = t0.elapsed().as_secs_f64() / (reps * w) as f64;
        if w == 8 {
            width8_per_field = per_field;
        }
        table.push(
            FigureRow::new("coalesce", format!("w={w}"))
                .col("per_field_s", per_field)
                .col("speedup_vs_serial", serial_per_field / per_field.max(1e-12)),
        );
    }
    let stats = svc.stats();
    table.push(
        FigureRow::new("serve_stats", "counters")
            .col("cache_hits", stats.cache_hits as f64)
            .col("cache_misses", stats.cache_misses as f64)
            .col("cache_evictions", stats.cache_evictions as f64)
            .col("groups_w1", stats.widths[0] as f64)
            .col("groups_w4", stats.widths[3] as f64)
            .col("groups_w8", stats.widths[7] as f64)
            .col("arena_leases", stats.arena.leases as f64)
            .col("arena_reuses", stats.arena.reuses as f64)
            .col("arena_held_bytes", stats.arena.held_bytes as f64),
    );
    print!("{}", table.render());
    emit_json("fig_serve", &table);
    println!("serve stats:\n{}", stats.render());
    let coalesce_ratio = serial_per_field / width8_per_field.max(1e-12);
    assert!(
        coalesce_ratio >= 2.0,
        "width-8 coalescing must be >= 2x serial per-field throughput \
         (serial {serial_per_field:.6}s vs coalesced {width8_per_field:.6}s \
         = {coalesce_ratio:.2}x)"
    );
}
