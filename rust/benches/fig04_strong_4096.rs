//! Figures 4 & 5: strong scaling of the 4096³ double-precision transform
//! on Cray XT5 — Alltoall (USEEVEN) vs Alltoallv series, the
//! communication-time series, the `a/P + d/P^(2/3)` fit (same data on
//! log-log and linear axes in the paper; one table here), and the §4.3
//! effective-bisection-bandwidth estimate (paper: 212 GB/s at 65,536
//! cores, ~6% of the 3,686 GB/s peak).

use p3dfft::bench::paper::{measured_strong_rows, strong_scaling_fit, strong_scaling_table};
use p3dfft::bench::Table;
use p3dfft::netmodel::Machine;

fn main() {
    let machine = Machine::cray_xt5();
    let n = 4096;
    let ps = [1024usize, 2048, 4096, 8192, 16384, 32768, 65536];
    let table = strong_scaling_table(
        "Fig. 4/5 (model): 4096^3 strong scaling on Cray XT5",
        n,
        &ps,
        &machine,
    );
    print!("{}", table.render());

    let fit = strong_scaling_fit(n, &ps, &machine);
    println!(
        "\nEq. 4 fit: T(P) = {:.4e}/P + {:.4e}/P^(2/3), R^2 = {:.6}",
        fit.a, fit.d, fit.r2
    );
    let ntot = (n as f64).powi(3);
    let bw = fit.effective_bisection_bw(ntot, 16.0, 4.0, 65536.0);
    let peak = 16.0 * 24.0 * 9.6e9; // the paper's 15x16x24 partition estimate
    println!(
        "effective bisection bandwidth at 65536 cores: {:.0} GB/s ({:.1}% of the \
         paper's 3686 GB/s peak estimate; paper measured 212 GB/s ≈ 6%)",
        bw / 1e9,
        100.0 * bw / peak
    );

    // Measured strong scaling at host scale (shape check only).
    println!("\nmeasured (host scale, 64^3):");
    let mut t = Table::new("Fig. 4 measured mini-series");
    for row in measured_strong_rows(64, &[(1, 1), (1, 2), (2, 2), (2, 4)], 3).unwrap() {
        t.push(row);
    }
    print!("{}", t.render());
}
