//! fig_tune: does the plan-time tuner pick the grid the measured
//! Fig.-3-style sweep actually ranks first?
//!
//! For each problem shape: (a) run the *exhaustive measured sweep* over
//! every Eq.-2-feasible `(m1, m2)` factorization of P on thread ranks
//! (blocking pipeline, the Fig. 3 protocol), and (b) ask the tuner for
//! its pick twice — on the fixed synthetic host profile (deterministic)
//! and on the calibrated profile (micro-probed). The `agree` column
//! records whether the tuner's `(m1, m2)` equals the measured winner —
//! the number the CI bench-smoke artifact tracks per PR.
//!
//! `--quick` / `P3DFFT_BENCH_QUICK=1` shrinks the shapes;
//! `P3DFFT_BENCH_JSON=PATH` appends the summary tables.

use p3dfft::bench::{emit_json, quick_mode, FigureRow, Table};
use p3dfft::coordinator::PlanSpec;
use p3dfft::tune::{
    autotune, grid_candidates, Candidate, MachineProfile, TuneOptions, TuneReport,
};

fn measured_sweep(dims: [usize; 3], p: usize, iters: usize) -> Vec<(usize, usize, f64)> {
    let mut out = Vec::new();
    for pg in grid_candidates(dims, p) {
        let cand = Candidate { m1: pg.m1, m2: pg.m2, use_even: false, overlap_chunks: 1 };
        let t = p3dfft::tune::refine::measure_candidate(dims, &cand, iters, 0xF16_7135)
            .expect("measured sweep run");
        out.push((pg.m1, pg.m2, t));
    }
    out.sort_by(|a, b| a.2.partial_cmp(&b.2).unwrap());
    out
}

fn model_pick(dims: [usize; 3], p: usize, profile: MachineProfile) -> TuneReport {
    let opts = TuneOptions {
        profile,
        // Match the measured sweep's axes: geometry only.
        explore_use_even: false,
        explore_overlap: false,
        ..TuneOptions::default()
    };
    autotune(dims, p, &opts).expect("tuner run")
}

fn main() {
    let quick = quick_mode();
    let shapes: Vec<([usize; 3], usize, usize)> = if quick {
        // (dims, P, iters)
        vec![([32, 32, 32], 4, 1), ([16, 24, 48], 4, 1)]
    } else {
        vec![([64, 64, 64], 8, 3), ([32, 48, 96], 8, 3)]
    };
    let mut agreements = 0usize;
    for (dims, p, iters) in &shapes {
        let (dims, p, iters) = (*dims, *p, *iters);
        let sweep = measured_sweep(dims, p, iters);
        let mut table = Table::new(format!(
            "fig_tune: {}x{}x{} on P={p} thread ranks (measured sweep vs tuner pick)",
            dims[0], dims[1], dims[2]
        ));
        for (rank, (m1, m2, t)) in sweep.iter().enumerate() {
            table.push(
                FigureRow::new("measured", format!("{m1}x{m2}"))
                    .col("rank", (rank + 1) as f64)
                    .col("pair_s", *t),
            );
        }
        let (best_m1, best_m2, best_t) = sweep[0];
        let synthetic = model_pick(dims, p, MachineProfile::nominal_host());
        let calibrated = model_pick(dims, p, MachineProfile::calibrated_quick());
        for (series, report) in
            [("tuner(synthetic)", &synthetic), ("tuner(calibrated)", &calibrated)]
        {
            let pick = &report.best().cand;
            let agree = pick.m1 == best_m1 && pick.m2 == best_m2;
            if series.contains("synthetic") && agree {
                agreements += 1;
            }
            table.push(
                FigureRow::new(series, format!("{}x{}", pick.m1, pick.m2))
                    .col("model_s", report.best().model_s)
                    .col("measured_best_s", best_t)
                    .col("agree", f64::from(agree)),
            );
        }
        print!("{}", table.render());
        emit_json("fig_tune", &table);
        println!(
            "measured best {best_m1}x{best_m2} ({best_t:.6}s) vs tuner picks: \
             synthetic {}x{}, calibrated {}x{}\n",
            synthetic.best().cand.m1,
            synthetic.best().cand.m2,
            calibrated.best().cand.m1,
            calibrated.best().cand.m2,
        );
        // The autotune API surface used by real callers: winner -> spec.
        let (spec, _) = PlanSpec::autotune(dims, p, &TuneOptions::default()).expect("autotune");
        assert_eq!(spec.p(), p, "autotuned spec must keep the rank count");
    }
    println!(
        "tuner (synthetic profile) agreed with the measured sweep on {agreements}/{} shapes",
        shapes.len()
    );
}
