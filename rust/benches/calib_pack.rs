//! Calibration bench: pack/unpack streaming bandwidth (σ_mem and the b
//! parameter of Eq. 3), for both the STRIDE1 transpose-embedding kernels
//! and the non-STRIDE1 contiguous slab kernels.

use p3dfft::bench::{measure, FigureRow, MeasureOpts, Table};
use p3dfft::fft::Complex;
use p3dfft::transpose::pack::{
    pack_x_to_y, pack_x_to_y_xyz, pack_y_to_z, unpack_x_to_y, unpack_x_to_y_xyz,
};
use p3dfft::util::SplitMix64;

fn main() {
    let mut table = Table::new("calib: pack/unpack bandwidth");
    for &n in &[64usize, 128, 256] {
        let (nz, ny, h) = (n / 2, n, n / 2 + 1);
        let vol_bytes = (nz * ny * h * std::mem::size_of::<Complex<f64>>()) as f64;
        let mut rng = SplitMix64::new(7);
        let input: Vec<Complex<f64>> =
            (0..nz * ny * h).map(|_| Complex::new(rng.next_normal(), 0.1)).collect();
        let mut buf = vec![Complex::<f64>::zero(); nz * ny * h];
        let mut out = vec![Complex::<f64>::zero(); nz * h * ny];

        let s = measure(MeasureOpts { warmup: 1, iterations: 7 }, || {
            pack_x_to_y(&input, nz, ny, h, 0, h, &mut buf);
        });
        table.push(
            FigureRow::new("pack_x_to_y (stride1 transpose)", format!("{n}"))
                .col("median_s", s.median)
                .col("gbs", 2.0 * vol_bytes / s.median / 1e9),
        );

        let s = measure(MeasureOpts { warmup: 1, iterations: 7 }, || {
            unpack_x_to_y(&buf, nz, h, ny, 0, ny, &mut out);
        });
        table.push(
            FigureRow::new("unpack_x_to_y (runs)", format!("{n}"))
                .col("median_s", s.median)
                .col("gbs", 2.0 * vol_bytes / s.median / 1e9),
        );

        let s = measure(MeasureOpts { warmup: 1, iterations: 7 }, || {
            pack_y_to_z(&input, nz, h, ny, 0, ny, &mut buf);
        });
        table.push(
            FigureRow::new("pack_y_to_z (stride1 large-stride)", format!("{n}"))
                .col("median_s", s.median)
                .col("gbs", 2.0 * vol_bytes / s.median / 1e9),
        );

        let s = measure(MeasureOpts { warmup: 1, iterations: 7 }, || {
            pack_x_to_y_xyz(&input, nz, ny, h, 0, h, &mut buf);
        });
        table.push(
            FigureRow::new("pack_x_to_y_xyz (slab memcpy)", format!("{n}"))
                .col("median_s", s.median)
                .col("gbs", 2.0 * vol_bytes / s.median / 1e9),
        );

        let s = measure(MeasureOpts { warmup: 1, iterations: 7 }, || {
            unpack_x_to_y_xyz(&buf, nz, h, ny, 0, ny, &mut out);
        });
        table.push(
            FigureRow::new("unpack_x_to_y_xyz (memcpy)", format!("{n}"))
                .col("median_s", s.median)
                .col("gbs", 2.0 * vol_bytes / s.median / 1e9),
        );
    }
    print!("{}", table.render());
}
