//! Kernel-throughput bench: lines/sec of the 1D execution layer for
//! contiguous and strided batches at representative pencil shapes —
//! three-way: per-line scalar execution vs the blocked tile driver on the
//! portable backend vs the blocked driver on the detected SIMD backend.
//!
//! The per-line baselines are reproduced locally (scalar `execute` per
//! contiguous line; element-by-element gather/scatter around a scalar
//! `execute` for column-major lines — the exact loop the seed's
//! `execute_strided` ran) so the before/after is measured in one binary
//! on one host. The portable-vs-SIMD pair isolates the explicit-SIMD win
//! from the blocking win. Feeds EXPERIMENTS.md §Perf; in CI the
//! quick-mode table is appended to the `BENCH_ci.json` artifact so
//! per-PR kernel throughput is tracked alongside the
//! fig03/fig_overlap/fig_tune tables.
//!
//! Provenance: a leading `meta` row records the detected ISA, the backend
//! the SIMD series ran on, and the compiled lane width `W`
//! ([`p3dfft::tile::TILE_LANES`]). The CI lane sweep rebuilds this bench
//! with `--features tile-lanes-4` / `tile-lanes-16` and appends to the
//! same JSON, so the sweep points are distinguished by their `lanes`
//! column.
//!
//! `--quick` / `P3DFFT_BENCH_QUICK=1` shrinks the sweep for the CI
//! bench-smoke job; `P3DFFT_BENCH_JSON=PATH` appends the table.

use p3dfft::bench::{emit_json, measure, quick_mode, FigureRow, MeasureOpts, Table};
use p3dfft::fft::{isa_summary, Backend, C2cPlan, Complex, Direction};
use p3dfft::tile::TILE_LANES;
use p3dfft::util::SplitMix64;

/// The seed's per-line strided execution: gather each column-major line
/// element by element, scalar FFT, scatter back — the baseline the
/// blocked tile gather replaces.
fn execute_strided_perline(
    plan: &C2cPlan<f64>,
    data: &mut [Complex<f64>],
    count: usize,
    stride: usize,
    line: &mut [Complex<f64>],
    scratch: &mut [Complex<f64>],
) {
    for b in 0..count {
        for (k, v) in line.iter_mut().enumerate() {
            *v = data[b + k * stride];
        }
        plan.execute(line, scratch);
        for (k, v) in line.iter().enumerate() {
            data[b + k * stride] = *v;
        }
    }
}

fn rand_data(len: usize, seed: u64) -> Vec<Complex<f64>> {
    let mut rng = SplitMix64::new(seed);
    (0..len).map(|_| Complex::new(rng.next_normal(), rng.next_normal())).collect()
}

fn main() {
    let quick = quick_mode();
    let opts = MeasureOpts { warmup: 1, iterations: if quick { 3 } else { 9 } };
    // (line length, lines per slab): pow-2, smooth and prime (Bluestein)
    // lengths at pencil-plane line counts, including a non-multiple of
    // the lane width to keep the ragged-tail paths in the measurement.
    let shapes: &[(usize, usize)] = if quick {
        &[(256, 120), (360, 64), (509, 32)]
    } else {
        &[(128, 512), (256, 256), (512, 256), (1024, 120), (360, 128), (509, 64)]
    };

    let detected = Backend::detect();
    let mut table = Table::new(format!(
        "fig_kernels: 1D execution layer, lines/sec (per-line vs blocked-portable vs \
         blocked-{}), W={}, {} iters",
        detected.name(),
        TILE_LANES,
        opts.iterations
    ));
    // Provenance row: detected ISA, the backend behind the `simd_mlps`
    // series, and the compiled lane width (the CI sweep's x-axis).
    table.push(
        FigureRow::new("meta", format!("isa={} backend={}", isa_summary(), detected.name()))
            .col("lanes", TILE_LANES as f64),
    );
    for &(n, count) in shapes {
        let portable = C2cPlan::<f64>::with_backend(n, Direction::Forward, Backend::Portable);
        let simd = C2cPlan::<f64>::with_backend(n, Direction::Forward, detected);
        let mut scratch =
            vec![Complex::<f64>::zero(); portable.scratch_len().max(simd.scratch_len())];
        let x = format!("n={n} lines={count}");

        // Contiguous back-to-back lines (the STRIDE1 pencil shape).
        let mut data = rand_data(n * count, n as u64);
        let s_perline = measure(opts, || {
            for line in data.chunks_exact_mut(n) {
                portable.execute(line, &mut scratch);
            }
        });
        let s_portable = measure(opts, || {
            portable.execute_batch(&mut data, &mut scratch);
        });
        let s_simd = measure(opts, || {
            simd.execute_batch(&mut data, &mut scratch);
        });
        table.push(
            FigureRow::new("contiguous", x.clone())
                .col("perline_mlps", count as f64 / s_perline.median / 1e6)
                .col("portable_mlps", count as f64 / s_portable.median / 1e6)
                .col("simd_mlps", count as f64 / s_simd.median / 1e6)
                .col("speedup_blocked", s_perline.median / s_portable.median)
                .col("speedup_simd", s_perline.median / s_simd.median)
                .col("lanes", TILE_LANES as f64),
        );

        // Column-major lines, stride == count (the XYZ-order plane shape
        // the strided stages transform).
        let mut data = rand_data(n * count, n as u64 + 1);
        let mut line = vec![Complex::<f64>::zero(); n];
        let s_perline = measure(opts, || {
            execute_strided_perline(&portable, &mut data, count, count, &mut line, &mut scratch);
        });
        let s_portable = measure(opts, || {
            portable.execute_strided(&mut data, count, count, &mut scratch);
        });
        let s_simd = measure(opts, || {
            simd.execute_strided(&mut data, count, count, &mut scratch);
        });
        table.push(
            FigureRow::new("strided", x)
                .col("perline_mlps", count as f64 / s_perline.median / 1e6)
                .col("portable_mlps", count as f64 / s_portable.median / 1e6)
                .col("simd_mlps", count as f64 / s_simd.median / 1e6)
                .col("speedup_blocked", s_perline.median / s_portable.median)
                .col("speedup_simd", s_perline.median / s_simd.median)
                .col("lanes", TILE_LANES as f64),
        );
    }
    print!("{}", table.render());
    emit_json("fig_kernels", &table);
    println!(
        "(mlps = million lines/sec; speedup_* = per-line median / blocked median; \
         simd series backend: {})",
        detected.name()
    );
}
