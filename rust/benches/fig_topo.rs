//! Topology ablation: flat fabric vs two-level node maps, measured and
//! modelled.
//!
//! Measured side: `test_sine` forward+backward pairs on thread ranks under
//! node maps {flat, 2 nodes, 4 nodes} (via `topology.cores_per_node` =
//! P, P/2, P/4), with and without chunked overlap. The payload must be
//! bit-identical across all maps — the node map only changes the peer
//! service order and the modeled `link_s` bucket (inter-node sends priced
//! at a nominal latency/bandwidth, never slept). Series labels carry the
//! node-map provenance so BENCH_ci.json rows are self-describing.
//!
//! Model side: `predict_two_level` at paper-like scale on a machine whose
//! inter-node bandwidth is 1/4 of node memory bandwidth — the
//! intra-node-first schedule (exchange `max(E_intra, E_inter)`) must
//! strictly beat the flat order (`E_intra + E_inter`) on every grid shape
//! that has both traffic classes.
//!
//! `--quick` / `P3DFFT_BENCH_QUICK=1` shrinks the measured side for the
//! CI bench-smoke job; `P3DFFT_BENCH_JSON=PATH` appends both tables.

use p3dfft::bench::{emit_json, quick_mode, sine_field, verify_roundtrip, FigureRow, Table};
use p3dfft::coordinator::{run_on_threads, PlanSpec};
use p3dfft::grid::ProcGrid;
use p3dfft::mpi::{NodeMap, PlacementPolicy};
use p3dfft::netmodel::{predict_two_level, Interconnect, Machine, ModelInput};
use p3dfft::util::timer::Stage;

fn main() {
    let quick = quick_mode();
    // ---- measured: host scale, node-map sweep -----------------------------
    let dims = if quick { [32, 32, 32] } else { [64, 64, 64] };
    let (m1, m2) = (2, 2);
    let p = m1 * m2;
    let iterations = if quick { 1 } else { 3 };
    let ks: &[usize] = if quick { &[1, 4] } else { &[1, 2, 4] };
    // cores_per_node = P (one node = flat), P/2 (two nodes), P/4 (four).
    let maps: &[(&str, usize)] = &[("flat-1node", p), ("2node", p / 2), ("4node", p / 4)];
    let mut table = Table::new(format!(
        "fig_topo (measured): {}x{}x{} on {m1}x{m2} thread ranks, {iterations} iters",
        dims[0], dims[1], dims[2]
    ));
    for &k in ks {
        let mut reference: Option<Vec<f64>> = None;
        for &(name, cores) in maps {
            let spec = PlanSpec::new(dims, ProcGrid::new(m1, m2))
                .unwrap()
                .with_overlap_chunks(k)
                .unwrap()
                .with_cores_per_node(Some(cores))
                .unwrap();
            let (nx, ny, nz) = (dims[0], dims[1], dims[2]);
            let report = run_on_threads(&spec, move |ctx| {
                let input = ctx.make_real_input(sine_field::<f64>(nx, ny, nz));
                let mut out = ctx.alloc_output();
                let mut back = ctx.alloc_input();
                // Warmup.
                ctx.forward(&input, &mut out)?;
                ctx.backward(&out, &mut back)?;
                ctx.state.timer.reset();
                let t0 = std::time::Instant::now();
                let mut worst = 0.0f64;
                for _ in 0..iterations {
                    ctx.forward(&input, &mut out)?;
                    ctx.backward(&out, &mut back)?;
                    worst = worst.max(verify_roundtrip(&input, &back, ctx.plan.normalization()));
                }
                let pair = t0.elapsed().as_secs_f64() / iterations as f64;
                // A payload digest to pin bit-identity across node maps.
                let digest: f64 = out.iter().take(64).map(|c| c.re + c.im).sum();
                Ok((ctx.max_over_ranks(pair), ctx.max_over_ranks(worst), digest))
            })
            .expect("topo bench run");
            let (pair_s, err, _) = report.per_rank[0];
            assert!(err < 1e-10, "roundtrip broke under {name} k={k}: {err:.3e}");
            let digests: Vec<f64> = report.per_rank.iter().map(|r| r.2).collect();
            match &reference {
                None => reference = Some(digests),
                Some(want) => assert_eq!(
                    want, &digests,
                    "node map {name} changed the spectrum at k={k}"
                ),
            }
            table.push(
                FigureRow::new(format!("measured/{name}"), format!("k={k}"))
                    .col("pair_s", pair_s)
                    .col("exchange_s", report.timer.get(Stage::Exchange))
                    .col("overlap_s", report.overlap())
                    .col("link_s", report.link()),
            );
        }
    }
    print!("{}", table.render());
    emit_json("fig_topo", &table);
    println!(
        "(link_s = modeled inter-node wire time, accounting only; \
         payloads asserted bit-identical across node maps)\n"
    );

    // ---- modelled: two-level schedule vs flat ------------------------------
    // A machine whose inter-node injection bandwidth is 1/4 of node memory
    // bandwidth (per node): the acceptance scenario for the topology-aware
    // schedule.
    let cpn = 16usize;
    let mem_bw = 2.0e9;
    let machine = Machine {
        name: "two-level",
        flops_per_core: 1.0e9,
        mem_bw_per_task: mem_bw,
        b_mem_accesses: 20.0,
        c_contention: 1.0,
        cores_per_node: cpn,
        interconnect: Interconnect::Clos {
            port_bw: cpn as f64 * mem_bw / 4.0,
            cores_per_node: cpn,
        },
        alltoallv_penalty: 1.0,
        msg_latency: 2.0e-6,
    };
    let pm = 1024usize;
    let nodes = NodeMap::new(pm, cpn, PlacementPolicy::Contiguous);
    let mut table = Table::new(format!(
        "fig_topo (model): 1024^3 on P={pm} cores, {cpn}/node, inter bw = intra/4"
    ));
    let mut aware_wins = 0usize;
    for (gm1, gm2) in [(8usize, 128usize), (16, 64), (32, 32)] {
        for k in [1usize, 4] {
            let inp = ModelInput::cubic(1024, gm1, gm2, machine.clone());
            let t = predict_two_level(&inp, k, &nodes);
            if t.aware_s < t.flat_s {
                aware_wins += 1;
            }
            table.push(
                FigureRow::new(format!("model/{gm1}x{gm2}"), format!("k={k}"))
                    .col("flat_s", t.flat_s)
                    .col("aware_s", t.aware_s)
                    .col("speedup", t.flat_s / t.aware_s.max(1e-30))
                    .col("row_intra", t.row_intra)
                    .col("col_intra", t.col_intra),
            );
        }
    }
    print!("{}", table.render());
    emit_json("fig_topo", &table);
    assert!(
        aware_wins >= 4,
        "topology-aware schedule should beat flat on at least 2 shapes x 2 chunk counts"
    );
    println!(
        "topology-aware schedule beats flat on {aware_wins}/6 modelled rows \
         (intra-node drains hidden behind inter-node flight)"
    );
}
