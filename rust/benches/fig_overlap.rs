//! Overlap ablation: chunked communication–compute overlap vs the paper's
//! blocking pipeline, measured and modelled.
//!
//! Measured side: `test_sine` forward+backward pairs on thread ranks with
//! `overlap_chunks` ∈ {1, 2, 4, 8}, reporting the per-stage breakdown —
//! `exchange_s` is the *exposed* wait only, `overlap_s` is exchange time
//! that was in flight while the rank packed/unpacked/transformed other
//! chunks. The blocking row (k = 1) has `overlap_s = 0` by construction;
//! rows with k > 1 must show exchange time migrating into the overlap
//! bucket while `pair_s` stays flat or improves (thread fabric latencies
//! are tiny, so the big wins belong to the modelled rows below).
//!
//! Model side: Eq.-1-style `predict_overlapped` at the paper's scale
//! (2048³ on 2048 cores, Cray XT5), where the exchange dominates and
//! pipelining it against compute is the main lever past the 2D
//! decomposition baseline (cf. CROFT arXiv:2002.04896, AccFFT
//! arXiv:1506.07933).
//!
//! `--quick` / `P3DFFT_BENCH_QUICK=1` shrinks the measured side for the
//! CI bench-smoke job; `P3DFFT_BENCH_JSON=PATH` appends both tables.

use p3dfft::bench::{emit_json, quick_mode, sine_field, verify_roundtrip, FigureRow, Table};
use p3dfft::coordinator::{run_on_threads, PlanSpec};
use p3dfft::grid::ProcGrid;
use p3dfft::netmodel::{predict, predict_overlapped, Machine, ModelInput};
use p3dfft::util::timer::Stage;

fn main() {
    let quick = quick_mode();
    // ---- measured: host scale ---------------------------------------------
    let dims = if quick { [48, 40, 32] } else { [96, 80, 72] };
    let ks: &[usize] = if quick { &[1, 2, 4] } else { &[1, 2, 4, 8] };
    let (m1, m2) = (2, 2);
    let iterations = if quick { 1 } else { 3 };
    let mut table = Table::new(format!(
        "fig_overlap (measured): {}x{}x{} on {m1}x{m2} thread ranks, {iterations} iters",
        dims[0], dims[1], dims[2]
    ));
    let mut blocking_pair = 0.0;
    for &k in ks {
        let spec = PlanSpec::new(dims, ProcGrid::new(m1, m2))
            .unwrap()
            .with_overlap_chunks(k)
            .unwrap();
        let (nx, ny, nz) = (dims[0], dims[1], dims[2]);
        let report = run_on_threads(&spec, move |ctx| {
            let input = ctx.make_real_input(sine_field::<f64>(nx, ny, nz));
            let mut out = ctx.alloc_output();
            let mut back = ctx.alloc_input();
            // Warmup.
            ctx.forward(&input, &mut out)?;
            ctx.backward(&out, &mut back)?;
            ctx.state.timer.reset();
            let t0 = std::time::Instant::now();
            let mut worst = 0.0f64;
            for _ in 0..iterations {
                ctx.forward(&input, &mut out)?;
                ctx.backward(&out, &mut back)?;
                worst = worst.max(verify_roundtrip(&input, &back, ctx.plan.normalization()));
            }
            let pair = t0.elapsed().as_secs_f64() / iterations as f64;
            Ok((ctx.max_over_ranks(pair), ctx.max_over_ranks(worst)))
        })
        .expect("overlap bench run");
        let (pair_s, err) = report.per_rank[0];
        assert!(err < 1e-10, "roundtrip broke at k={k}: {err:.3e}");
        if k == 1 {
            blocking_pair = pair_s;
        }
        table.push(
            FigureRow::new("measured", format!("k={k}"))
                .col("pair_s", pair_s)
                .col("speedup", blocking_pair / pair_s.max(1e-12))
                .col("compute_s", report.compute())
                .col("pack_s", report.timer.get(Stage::Pack))
                .col("exchange_s", report.timer.get(Stage::Exchange))
                .col("unpack_s", report.timer.get(Stage::Unpack))
                .col("overlap_s", report.overlap()),
        );
    }
    print!("{}", table.render());
    emit_json("fig_overlap", &table);
    println!("(exchange_s = exposed wait; overlap_s = in flight behind pack/unpack/compute)\n");

    // ---- modelled: paper scale --------------------------------------------
    let machine = Machine::cray_xt5();
    let inp = ModelInput::cubic(2048, 16, 128, machine);
    let c = predict(&inp);
    let mut table = Table::new(format!(
        "fig_overlap (model, Eq.-1 style): 2048^3 on 16x128 = {} cores, {}",
        inp.p(),
        inp.machine.name
    ));
    for k in [1usize, 2, 4, 8, 16, 32, 64] {
        let t = predict_overlapped(&inp, k);
        table.push(
            FigureRow::new("model", format!("k={k}"))
                .col("pair_s", 2.0 * t)
                .col("speedup", c.total() / t)
                .col("exposed_exch_s", 2.0 * (t - (c.compute + c.memory) - k as f64 * c.latency))
                .col("latency_s", 2.0 * k as f64 * c.latency),
        );
    }
    print!("{}", table.render());
    emit_json("fig_overlap", &table);
    let best = [1usize, 2, 4, 8, 16, 32, 64]
        .into_iter()
        .min_by(|&a, &b| {
            predict_overlapped(&inp, a).partial_cmp(&predict_overlapped(&inp, b)).unwrap()
        })
        .unwrap();
    println!(
        "predicted best chunk count: k={best} ({:.4}s vs blocking {:.4}s)",
        predict_overlapped(&inp, best),
        c.total()
    );
}
