//! Figure 3: performance vs processor-grid aspect ratio M1 x M2.
//!
//! Paper protocol: 2048³ on 1024 cores, Cray XT5 (Kraken, 12 cores/node)
//! and Sun/AMD (Ranger, 16 cores/node); time-to-solution per M1 x M2 bar.
//! Expected shape: time rises once M1 crosses the cores-per-node
//! threshold; the square grid 32x32 is NOT optimal.
//!
//! Emits (a) model rows at the paper's exact scale on both machines and
//! (b) measured rows from a thread-rank sweep at host scale.
//!
//! `--quick` (or `P3DFFT_BENCH_QUICK=1`) shrinks the measured sweep for
//! the CI bench-smoke job; `P3DFFT_BENCH_JSON=PATH` appends every table
//! to the `BENCH_ci.json` summary.

use p3dfft::bench::paper::measured_strong_rows;
use p3dfft::bench::{emit_json, quick_mode, FigureRow, Table};
use p3dfft::grid::ProcGrid;
use p3dfft::netmodel::{predict, Machine, ModelInput};

fn main() {
    let quick = quick_mode();
    for machine in [Machine::cray_xt5(), Machine::ranger()] {
        let n = 2048;
        let p = 1024;
        let mut table = Table::new(format!(
            "Fig. 3 (model): 2048^3 on 1024 cores, {} ({} cores/node)",
            machine.name, machine.cores_per_node
        ));
        for pg in ProcGrid::factorizations(p) {
            if pg.m1 > n / 2 + 1 || pg.m2 > n {
                continue;
            }
            let mut input = ModelInput::cubic(n, pg.m1, pg.m2, machine.clone());
            input.use_even = machine.name.contains("Cray");
            let c = predict(&input);
            table.push(
                FigureRow::new("model", format!("{}x{}", pg.m1, pg.m2))
                    .col("pair_s", 2.0 * c.total())
                    .col("row_s", 2.0 * c.row_exchange)
                    .col("col_s", 2.0 * c.col_exchange)
                    .col("on_node_row", f64::from(pg.m1 <= machine.cores_per_node)),
            );
        }
        print!("{}", table.render());
        emit_json("fig03_aspect_ratio", &table);

        // The paper's headline check: best non-square beats the square grid.
        let square = 2.0 * predict(&ModelInput::cubic(n, 32, 32, machine.clone())).total();
        let best = ProcGrid::factorizations(p)
            .into_iter()
            .filter(|pg| pg.m1 <= n / 2 + 1 && pg.m2 <= n)
            .map(|pg| {
                (pg, 2.0 * predict(&ModelInput::cubic(n, pg.m1, pg.m2, machine.clone())).total())
            })
            .min_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
            .unwrap();
        println!(
            "best geometry {}x{} = {:.4}s vs square 32x32 = {:.4}s ({}x better)\n",
            best.0.m1,
            best.0.m2,
            best.1,
            square,
            square / best.1
        );
    }

    // Measured mini-sweep: all factorizations at host scale (quick mode
    // shrinks the grid and rank count for the CI smoke job).
    let (n, p, iters) = if quick { (32, 4, 1) } else { (64, 8, 3) };
    println!("measured sweep on this host ({n}^3, P = {p} thread ranks):");
    let mut table = Table::new(format!("Fig. 3 (measured, host scale, {n}^3 P={p})"));
    let pgrids: Vec<(usize, usize)> =
        ProcGrid::factorizations(p).into_iter().map(|g| (g.m1, g.m2)).collect();
    for row in measured_strong_rows(n, &pgrids, iters).unwrap() {
        table.push(row);
    }
    print!("{}", table.render());
    emit_json("fig03_aspect_ratio", &table);
}
