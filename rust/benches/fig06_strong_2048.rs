//! Figure 6: strong scaling of the 2048³ transform on Cray XT5 (time and
//! TFLOPS, the paper shows linear + log-log of the same series).

use p3dfft::bench::paper::{measured_strong_rows, strong_scaling_table};
use p3dfft::bench::Table;
use p3dfft::netmodel::Machine;

fn main() {
    let table = strong_scaling_table(
        "Fig. 6 (model): 2048^3 strong scaling on Cray XT5",
        2048,
        &[256, 512, 1024, 2048, 4096, 8192, 16384],
        &Machine::cray_xt5(),
    );
    print!("{}", table.render());

    println!("\nmeasured (host scale, 48^3):");
    let mut t = Table::new("Fig. 6 measured mini-series");
    for row in measured_strong_rows(48, &[(1, 1), (1, 2), (2, 2), (2, 4)], 3).unwrap() {
        t.push(row);
    }
    print!("{}", t.render());
}
