//! Calibration bench: serial 1D FFT throughput of the native engine
//! (the paper's F parameter) across algorithm classes and sizes.
//!
//! Feeds `netmodel::calibrate` and the §Perf log in EXPERIMENTS.md.

use p3dfft::bench::{measure, FigureRow, MeasureOpts, Table};
use p3dfft::fft::{C2cPlan, Complex, Direction, R2cPlan};
use p3dfft::util::SplitMix64;

fn main() {
    let mut table = Table::new("calib: serial FFT throughput (native engine)");
    let batch_elems = 1 << 20; // ~1M complex elements per run

    for &n in &[64usize, 128, 256, 512, 1024, 2048, 4096, 48, 360, 1000, 97, 1009] {
        let batch = (batch_elems / n).max(1);
        let plan = C2cPlan::<f64>::new(n, Direction::Forward);
        let algo = if n.is_power_of_two() {
            "pow2"
        } else if p3dfft::fft::factor::is_smooth(n) {
            "mixed"
        } else {
            "bluestein"
        };
        let mut rng = SplitMix64::new(n as u64);
        let mut data: Vec<Complex<f64>> = (0..batch * n)
            .map(|_| Complex::new(rng.next_normal(), rng.next_normal()))
            .collect();
        let mut scratch = vec![Complex::zero(); plan.scratch_len()];
        let s = measure(MeasureOpts { warmup: 1, iterations: 5 }, || {
            plan.execute_batch(&mut data, &mut scratch);
        });
        let flops = batch as f64 * 5.0 * n as f64 * (n as f64).log2();
        table.push(
            FigureRow::new(algo, format!("{n}"))
                .col("batch", batch as f64)
                .col("median_s", s.median)
                .col("gflops", flops / s.median / 1e9),
        );
    }

    // R2C at the pencil-relevant sizes (half the work of C2C).
    for &n in &[512usize, 1024, 2048] {
        let batch = (batch_elems / n).max(1);
        let plan = R2cPlan::<f64>::new(n);
        let mut rng = SplitMix64::new(n as u64);
        let input: Vec<f64> = (0..batch * n).map(|_| rng.next_normal()).collect();
        let mut out = vec![Complex::zero(); batch * plan.out_len()];
        let mut scratch = vec![Complex::zero(); plan.scratch_len()];
        let s = measure(MeasureOpts { warmup: 1, iterations: 5 }, || {
            plan.execute_batch(&input, &mut out, &mut scratch);
        });
        let flops = batch as f64 * 2.5 * n as f64 * (n as f64).log2();
        table.push(
            FigureRow::new("r2c", format!("{n}"))
                .col("batch", batch as f64)
                .col("median_s", s.median)
                .col("gflops", flops / s.median / 1e9),
        );
    }
    print!("{}", table.render());
}
