//! Figure 9: weak scaling — 512³/16 → 8192³/65,536 cores with core count
//! ×8 per grid-doubling and a log(N) factor in the efficiency definition.
//! The paper's headline: 45% efficiency from 128 to 65,536 cores.

use p3dfft::bench::paper::weak_scaling_table;
use p3dfft::bench::workload::sine_field;
use p3dfft::bench::{FigureRow, Table};
use p3dfft::coordinator::{run_on_threads, PlanSpec};
use p3dfft::grid::ProcGrid;
use p3dfft::netmodel::model::weak_efficiency;
use p3dfft::netmodel::Machine;

fn main() {
    let (table, eff) = weak_scaling_table(&Machine::cray_xt5());
    print!("{}", table.render());
    println!(
        "\nweak-scaling efficiency 128 -> 65536 cores (model): {:.1}%  [paper: 45%]",
        100.0 * eff
    );

    // Measured weak scaling on thread ranks: work per rank held at ~32^3.
    println!("\nmeasured weak scaling on this host (32^3 per rank):");
    let mut t = Table::new("Fig. 9 measured mini-series");
    let series: [([usize; 3], (usize, usize)); 3] =
        [([32, 32, 32], (1, 1)), ([64, 32, 32], (1, 2)), ([64, 64, 32], (2, 2))];
    let mut pts: Vec<(usize, f64, f64)> = Vec::new();
    for (dims, (m1, m2)) in series {
        let spec = PlanSpec::new(dims, ProcGrid::new(m1, m2)).unwrap();
        let report = run_on_threads(&spec, move |ctx| {
            let input =
                ctx.make_real_input(sine_field::<f64>(dims[0], dims[1], dims[2]));
            let mut out = ctx.alloc_output();
            let mut back = ctx.alloc_input();
            ctx.forward(&input, &mut out)?;
            ctx.backward(&out, &mut back)?;
            let t0 = std::time::Instant::now();
            for _ in 0..3 {
                ctx.forward(&input, &mut out)?;
                ctx.backward(&out, &mut back)?;
            }
            Ok(ctx.max_over_ranks(t0.elapsed().as_secs_f64() / 3.0))
        })
        .unwrap();
        let p = m1 * m2;
        let work = (dims[0] * dims[1] * dims[2]) as f64;
        pts.push((p, report.per_rank[0], work));
        t.push(
            FigureRow::new("measured", format!("{}x{}x{}@{p}", dims[0], dims[1], dims[2]))
                .col("pair_s", report.per_rank[0]),
        );
    }
    print!("{}", t.render());
    // Host-scale efficiency (1 -> 4 ranks). On a single-core host threads
    // serialise, so the *informative* number is still the model one above;
    // we report the measured value for completeness.
    let (p1, t1, w1) = pts[0];
    let (p2, t2, w2) = pts[2];
    let ideal_t2 = t1 * (w2 / w1) / (p2 as f64 / p1 as f64);
    println!(
        "measured host weak efficiency 1 -> 4 ranks: {:.1}% (threads share {} cpu core(s))",
        100.0 * ideal_t2 / t2,
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
    );
    let _ = weak_efficiency(1, 1, 1.0, 2, 8, 1.0); // keep the API exercised
}
