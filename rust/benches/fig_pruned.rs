//! Pruned transforms + fused convolution: the wire-volume and stage-count
//! wins the truncation machinery buys.
//!
//! Measured side 1 — exchange volume: forward transforms on a `1×P` grid,
//! full vs 2/3-spherical truncation. With `M1 = 1` the X→Y transpose is a
//! rank-local copy (no fabric traffic), so `report.bytes` isolates the
//! Y→Z alltoallv the truncation prunes. With blocking exchanges the
//! byte ratio is exactly `(h·ny) / retained_pairs` (the self-block is
//! uncounted on both sides and the off-diagonal sum is symmetric), e.g.
//! `544/169 ≈ 3.22` for 32³ under the 2/3 rule — comfortably above the
//! ≥ 2.5× acceptance bar.
//!
//! Measured side 2 — fused convolution: `convolve` vs the unfused
//! forward + forward + pointwise product + backward sequence on a `2×2`
//! grid. The fused chain must execute exactly two fewer transpose stages
//! (asserted from the stage-graph descriptions); wall times for both are
//! reported.
//!
//! `--quick` / `P3DFFT_BENCH_QUICK=1` shrinks grids for the CI
//! bench-smoke job; `P3DFFT_BENCH_JSON=PATH` appends the table.

use p3dfft::bench::{emit_json, quick_mode, sine_field, FigureRow, Table};
use p3dfft::coordinator::{run_on_threads, Engine, PlanSpec, RankPlan};
use p3dfft::grid::ProcGrid;
use p3dfft::{PruneRule, Truncation};

fn main() {
    let quick = quick_mode();
    let n = if quick { 32usize } else { 64 };
    let dims = [n, n, n];
    let iterations = if quick { 1usize } else { 3 };

    // ---- exchange volume: full vs 2/3-truncated forward -------------------
    let p = 4usize;
    let rule = PruneRule::new(dims, Truncation::Spherical23);
    let predicted = (rule.h * rule.ny) as f64 / rule.retained_pairs() as f64;
    let run_fwd = |trunc: Option<Truncation>| {
        let mut spec = PlanSpec::new(dims, ProcGrid::new(1, p)).unwrap();
        if let Some(t) = trunc {
            spec = spec.with_truncation(t);
        }
        let (nx, ny, nz) = (dims[0], dims[1], dims[2]);
        run_on_threads(&spec, move |ctx| {
            let input = ctx.make_real_input(sine_field::<f64>(nx, ny, nz));
            let mut out = ctx.alloc_output();
            let t0 = std::time::Instant::now();
            for _ in 0..iterations {
                ctx.forward(&input, &mut out)?;
            }
            Ok(ctx.max_over_ranks(t0.elapsed().as_secs_f64() / iterations as f64))
        })
        .expect("fig_pruned forward run")
    };
    let full = run_fwd(None);
    let pruned = run_fwd(Some(Truncation::Spherical23));
    let ratio = full.bytes as f64 / pruned.bytes.max(1) as f64;

    let mut table = Table::new(format!(
        "fig_pruned: {n}^3 forward on 1x{p} ranks (Y->Z leg only), {iterations} iters"
    ));
    table.push(
        FigureRow::new("forward/full", format!("N={n}"))
            .col("bytes", full.bytes as f64)
            .col("wall_s", full.per_rank[0]),
    );
    table.push(
        FigureRow::new("forward/spherical23", format!("N={n}"))
            .col("bytes", pruned.bytes as f64)
            .col("wall_s", pruned.per_rank[0])
            .col("byte_ratio", ratio)
            .col("predicted_ratio", predicted),
    );
    assert!(
        ratio >= 2.5,
        "2/3-rule truncation must cut the Y->Z exchange bytes >= 2.5x \
         (measured {ratio:.2}x, predicted {predicted:.2}x)"
    );

    // ---- fused convolution vs unfused sequence ----------------------------
    let cdims = if quick { [32, 32, 32] } else { [64, 64, 64] };
    let cspec = PlanSpec::new(cdims, ProcGrid::new(2, 2)).unwrap();
    let probe = RankPlan::<f64>::new(&cspec, 0, Engine::Native).unwrap();
    let transposes = |d: &str| {
        d.split(" -> ").filter(|s| s.starts_with("xy-") || s.starts_with("yz-")).count()
    };
    let fused_stages = transposes(&probe.describe_convolve().expect("convolve graph"));
    let unfused_stages =
        2 * transposes(&probe.describe_forward()) + transposes(&probe.describe_backward());
    assert_eq!(
        fused_stages + 2,
        unfused_stages,
        "fused convolve must skip exactly two interior transpose stages"
    );

    let (nx, ny, nz) = (cdims[0], cdims[1], cdims[2]);
    let fused = run_on_threads(&cspec, move |ctx| {
        let sf = sine_field::<f64>(nx, ny, nz);
        let a = ctx.make_real_input(&sf);
        let b = ctx.make_real_input(|x, y, z| sf(z, x, y));
        let mut out = ctx.alloc_input();
        ctx.convolve(&a, &b, &mut out)?; // warmup
        let t0 = std::time::Instant::now();
        for _ in 0..iterations {
            ctx.convolve(&a, &b, &mut out)?;
        }
        Ok(ctx.max_over_ranks(t0.elapsed().as_secs_f64() / iterations as f64))
    })
    .expect("fig_pruned fused run");
    let unfused = run_on_threads(&cspec, move |ctx| {
        let sf = sine_field::<f64>(nx, ny, nz);
        let a = ctx.make_real_input(&sf);
        let b = ctx.make_real_input(|x, y, z| sf(z, x, y));
        let mut ah = ctx.alloc_output();
        let mut bh = ctx.alloc_output();
        let mut out = ctx.alloc_input();
        ctx.forward(&a, &mut ah)?; // warmup
        let t0 = std::time::Instant::now();
        for _ in 0..iterations {
            ctx.forward(&a, &mut ah)?;
            ctx.forward(&b, &mut bh)?;
            for (x, y) in ah.iter_mut().zip(&bh) {
                *x = *x * *y;
            }
            ctx.backward(&ah, &mut out)?;
        }
        Ok(ctx.max_over_ranks(t0.elapsed().as_secs_f64() / iterations as f64))
    })
    .expect("fig_pruned unfused run");
    table.push(
        FigureRow::new("convolve/fused", format!("N={}", cdims[0]))
            .col("wall_s", fused.per_rank[0])
            .col("transpose_stages", fused_stages as f64),
    );
    table.push(
        FigureRow::new("convolve/unfused", format!("N={}", cdims[0]))
            .col("wall_s", unfused.per_rank[0])
            .col("transpose_stages", unfused_stages as f64),
    );

    print!("{}", table.render());
    emit_json("fig_pruned", &table);
    println!(
        "2/3-rule truncation cut Y->Z exchange bytes {ratio:.2}x (predicted {predicted:.2}x); \
         fused convolve ran {fused_stages} transpose stages vs {unfused_stages} unfused"
    );
}
