//! Figure 8: strong scaling of the 512³ transform on Cray XT5 — the
//! smallest grid the paper reports; latency and per-node effects matter
//! most here, so the model rows include the per-message term explicitly.

use p3dfft::bench::paper::strong_scaling_table;
use p3dfft::bench::{FigureRow, Table};
use p3dfft::netmodel::{predict, Machine, ModelInput};

fn main() {
    let machine = Machine::cray_xt5();
    let table = strong_scaling_table(
        "Fig. 8 (model): 512^3 strong scaling on Cray XT5",
        512,
        &[16, 32, 64, 128, 256, 512, 1024],
        &machine,
    );
    print!("{}", table.render());

    // Cost decomposition at the extremes (where Fig. 8 flattens out).
    let mut t = Table::new("Fig. 8: cost decomposition (model, best geometry 12xM2)");
    for &p in &[16usize, 256, 1024] {
        let m1 = 12.min(p);
        let c = predict(&ModelInput::cubic(512, m1, p / m1, machine.clone()));
        t.push(
            FigureRow::new("model", format!("{p}"))
                .col("compute_s", 2.0 * c.compute)
                .col("memory_s", 2.0 * c.memory)
                .col("network_s", 2.0 * (c.row_exchange + c.col_exchange))
                .col("latency_s", 2.0 * c.latency),
        );
    }
    print!("{}", t.render());
}
