//! Figure 10: 1D (slab) vs 2D (pencil) decomposition, 2048³ on Cray XT5.
//!
//! Expected shape: 1D (one transpose) wins at moderate P; the gap narrows
//! toward P = N; past P = N the 1D line *ends* (only N slabs exist) while
//! 2D keeps scaling — the central scalability argument of the paper.

use p3dfft::bench::paper::best_pgrid_2d;
use p3dfft::bench::workload::sine_field;
use p3dfft::bench::{FigureRow, Table};
use p3dfft::coordinator::{run_on_threads, PlanSpec};
use p3dfft::grid::ProcGrid;
use p3dfft::netmodel::{predict, Machine, ModelInput};

fn main() {
    let machine = Machine::cray_xt5();
    let n = 2048usize;
    let mut table = Table::new("Fig. 10 (model): 1D vs 2D, 2048^3 on Cray XT5");
    let mut crossover_reported = false;
    for &p in &[256usize, 512, 1024, 2048, 4096, 8192] {
        let two_d = best_pgrid_2d(n, p, &machine, false);
        table.push(
            FigureRow::new("2d", format!("{p}"))
                .col("pair_s", two_d.2)
                .col("m1", two_d.0 as f64)
                .col("m2", two_d.1 as f64),
        );
        if p <= n {
            // 1D: 1 x P slabs (no ROW exchange at all).
            let one_d = 2.0 * predict(&ModelInput::cubic(n, 1, p, machine.clone())).total();
            table.push(FigureRow::new("1d", format!("{p}")).col("pair_s", one_d));
            if one_d > two_d.2 && !crossover_reported {
                println!("note: 2D overtakes 1D already at P = {p}");
                crossover_reported = true;
            }
        } else {
            table.push(FigureRow::new("1d", format!("{p}")).col("pair_s", f64::NAN));
        }
    }
    print!("{}", table.render());
    println!("\n(1d rows are NaN past P = N = {n}: no slabs left — the 2D version keeps scaling)");

    // Measured comparison at host scale: 32^3 on 1x4 vs 2x2 thread ranks.
    println!("\nmeasured (host scale, 32^3, P = 4):");
    let mut t = Table::new("Fig. 10 measured");
    for (label, m1, m2) in [("1d (1x4)", 1usize, 4usize), ("2d (2x2)", 2, 2)] {
        let spec = PlanSpec::new([32, 32, 32], ProcGrid::new(m1, m2)).unwrap();
        let report = run_on_threads(&spec, move |ctx| {
            let input = ctx.make_real_input(sine_field::<f64>(32, 32, 32));
            let mut out = ctx.alloc_output();
            let mut back = ctx.alloc_input();
            ctx.forward(&input, &mut out)?;
            ctx.backward(&out, &mut back)?;
            let t0 = std::time::Instant::now();
            for _ in 0..5 {
                ctx.forward(&input, &mut out)?;
                ctx.backward(&out, &mut back)?;
            }
            Ok(ctx.max_over_ranks(t0.elapsed().as_secs_f64() / 5.0))
        })
        .unwrap();
        t.push(
            FigureRow::new(label, "4")
                .col("pair_s", report.per_rank[0])
                .col("comm_s", report.comm()),
        );
    }
    print!("{}", t.render());
}
