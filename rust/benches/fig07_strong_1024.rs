//! Figure 7: strong scaling of the 1024³ transform on Cray XT5.

use p3dfft::bench::paper::strong_scaling_table;
use p3dfft::netmodel::Machine;

fn main() {
    let table = strong_scaling_table(
        "Fig. 7 (model): 1024^3 strong scaling on Cray XT5",
        1024,
        &[64, 128, 256, 512, 1024, 2048, 4096],
        &Machine::cray_xt5(),
    );
    print!("{}", table.render());
}
