"""AOT emitter: lower every L2 stage the Rust plan needs to HLO *text*.

Interchange format is HLO text, NOT a serialized HloModuleProto: jax >= 0.5
emits protos with 64-bit instruction ids which xla_extension 0.5.1 (behind
the Rust ``xla`` crate) rejects; the text parser reassigns ids and
round-trips cleanly (see /opt/xla-example/README.md).

Usage (from python/):  python -m compile.aot --out-dir ../artifacts \
    [--grid 32,32,32 --pgrid 2,2] [--dtypes f32,f64]

Emits one ``<stage>_b<batch>_n<n>_<dtype>.hlo.txt`` per distinct
(stage, batch, n, dtype) that the given grid/procgrid decomposition
produces, plus ``manifest.txt`` that the Rust runtime reads.  The
decomposition arithmetic here intentionally mirrors ``rust/src/grid`` —
the integration test checks they agree.
"""

import argparse
import os

import jax
import jax.numpy as jnp

jax.config.update("jax_enable_x64", True)

from jax._src.lib import xla_client as xc

from . import model


def block_sizes(length: int, parts: int):
    """Split ``length`` into ``parts`` contiguous blocks, remainder to the
    lowest ranks — the same convention as rust/src/grid/decompose.rs."""
    base, extra = divmod(length, parts)
    return [base + 1 if i < extra else base for i in range(parts)]


def stage_set(nx: int, ny: int, nz: int, m1: int, m2: int):
    """All (stage, batch, n) combos the distributed plan will execute.

    Pencil shapes follow Table 1 (STRIDE1 defined, transform axis
    innermost): X-pencil (nz/m2, ny/m1, nx); Y-pencil (nz/m2, h/m1, ny);
    Z-pencil (h/m1, ny/m2, nz), h = nx/2+1.
    """
    h = nx // 2 + 1
    ny1 = block_sizes(ny, m1)
    nz2 = block_sizes(nz, m2)
    h1 = block_sizes(h, m1)
    ny2 = block_sizes(ny, m2)
    combos = set()
    for a in ny1:
        for b in nz2:
            combos.add(("x_r2c", a * b, nx))
            combos.add(("x_c2r", a * b, nx))
    for a in h1:
        for b in nz2:
            combos.add(("c2c_fwd", a * b, ny))
            combos.add(("c2c_bwd", a * b, ny))
    for a in h1:
        for b in ny2:
            combos.add(("c2c_fwd", a * b, nz))
            combos.add(("c2c_bwd", a * b, nz))
            combos.add(("cheby", a * b, nz))
    return sorted(combos)


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    # CRITICAL: the default printer elides large array constants as
    # "{...}", which the consumer's HLO text parser silently reads as
    # ZEROS — the DFT/twiddle matrices are baked-in constants, so the
    # default text would compute all-zero spectra. Print them in full.
    opts = xc._xla.HloPrintOptions()
    opts.print_large_constants = True
    # The consumer's (older) parser rejects modern metadata attributes
    # (source_end_line etc.); strip metadata entirely.
    opts.print_metadata = False
    return comp.as_hlo_module().to_string(opts)


_DTYPES = {"f32": jnp.float32, "f64": jnp.float64}


def lower_stage(stage: str, batch: int, n: int, dtype_name: str) -> str:
    fn = model.make_stage_fn(stage)
    args = model.stage_example_args(stage, batch, n, dtype=_DTYPES[dtype_name])
    return to_hlo_text(jax.jit(fn).lower(*args))


def stage_io_arity(stage: str):
    ins = {"x_r2c": 1, "c2c_fwd": 2, "c2c_bwd": 2, "x_c2r": 2, "cheby": 1,
           "fft3d_r2c": 1}
    outs = {"x_r2c": 2, "c2c_fwd": 2, "c2c_bwd": 2, "x_c2r": 1, "cheby": 1,
            "fft3d_r2c": 2}
    return ins[stage], outs[stage]


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--grid", default="32,32,32",
                    help="Nx,Ny,Nz of the e2e artifact set")
    ap.add_argument("--pgrid", default="2,2", help="M1,M2 processor grid")
    ap.add_argument("--dtypes", default="f32,f64")
    ap.add_argument("--fused-cube", type=int, default=16,
                    help="also emit a fused whole-3D R2C artifact for an "
                         "N^3 cube (runtime smoke test); 0 disables")
    args = ap.parse_args()

    nx, ny, nz = (int(v) for v in args.grid.split(","))
    m1, m2 = (int(v) for v in args.pgrid.split(","))
    dtypes = [d for d in args.dtypes.split(",") if d]
    os.makedirs(args.out_dir, exist_ok=True)

    manifest = ["# p3dfft artifact manifest v1",
                "# file\tstage\tbatch\tn\tdtype\tn_inputs\tn_outputs"]
    combos = stage_set(nx, ny, nz, m1, m2)
    if args.fused_cube:
        combos.append(("fft3d_r2c", args.fused_cube * args.fused_cube,
                       args.fused_cube))
    total = 0
    for stage, batch, n in combos:
        for dt in dtypes:
            name = f"{stage}_b{batch}_n{n}_{dt}.hlo.txt"
            path = os.path.join(args.out_dir, name)
            text = lower_stage(stage, batch, n, dt)
            with open(path, "w") as f:
                f.write(text)
            n_in, n_out = stage_io_arity(stage)
            manifest.append(f"{name}\t{stage}\t{batch}\t{n}\t{dt}\t{n_in}\t{n_out}")
            total += 1
            print(f"  wrote {name} ({len(text)} chars)")
    with open(os.path.join(args.out_dir, "manifest.txt"), "w") as f:
        f.write("\n".join(manifest) + "\n")
    print(f"emitted {total} artifacts + manifest to {args.out_dir}")


if __name__ == "__main__":
    main()
