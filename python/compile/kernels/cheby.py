"""Chebyshev (cosine) transform as a Pallas matmul kernel.

P3DFFT offers a Chebyshev transform for the third dimension of wall-bounded
problems (two periodic directions + Chebyshev in the rigid-wall direction).
The Chebyshev transform of samples on the Gauss-Lobatto grid is a DCT-I; as
with the DFT we express it as a matmul so the MXU does the work.

Convention (matches scipy.fft.dct(type=1) unnormalised, and the Rust
``fft::dct`` module):

    Y_k = x_0 + (-1)^k x_{N-1} + 2 * sum_{j=1..N-2} x_j cos(pi j k / (N-1))

DCT-I is its own inverse up to the factor 2(N-1).
"""

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl


def cheby_matrix(n: int, dtype=jnp.float32):
    """Dense DCT-I matrix C with Y = X @ C for X of shape (B, n)."""
    j = np.arange(n)[:, None]
    k = np.arange(n)[None, :]
    c = 2.0 * np.cos(np.pi * j * k / (n - 1))
    c[0, :] = 1.0
    c[n - 1, :] = (-1.0) ** np.arange(n)
    return jnp.asarray(c, dtype=dtype)


def _dct_kernel(x_ref, c_ref, o_ref):
    o_ref[...] = x_ref[...] @ c_ref[...]


def pallas_dct1(x, *, block_b: int | None = None):
    """Batched DCT-I over the last axis of a (B, N) array via one matmul."""
    b, n = x.shape
    blk = block_b or min(b, 256)
    while b % blk != 0:
        blk -= 1
    c = cheby_matrix(n, dtype=x.dtype)
    if blk >= b:
        # Single block: no grid loop (grid-free lowering is what the AOT
        # consumer's older XLA executes correctly; see kernels/dft.py).
        return pl.pallas_call(
            _dct_kernel,
            out_shape=jax.ShapeDtypeStruct((b, n), x.dtype),
            interpret=True,
        )(x, c)
    return pl.pallas_call(
        _dct_kernel,
        grid=(b // blk,),
        in_specs=[
            pl.BlockSpec((blk, n), lambda i: (i, 0)),
            pl.BlockSpec((n, n), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((blk, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, n), x.dtype),
        interpret=True,
    )(x, c)
