"""L1 Pallas kernels for the P3DFFT reproduction.

All kernels run under ``interpret=True`` (CPU PJRT cannot execute Mosaic
custom-calls; see DESIGN.md §Hardware-Adaptation).  Complex data is carried
as separate real/imaginary planes so every matmul is a *real* matmul and
MXU-eligible on real hardware.
"""

from .dft import (
    dft_matrices,
    pallas_dft_c2c,
    pallas_dft_r2c,
    pallas_dft_c2r,
    pallas_dft_four_step,
)
from .transpose import pallas_transpose_2d
from .cheby import pallas_dct1, cheby_matrix

__all__ = [
    "dft_matrices",
    "pallas_dft_c2c",
    "pallas_dft_r2c",
    "pallas_dft_c2r",
    "pallas_dft_four_step",
    "pallas_transpose_2d",
    "pallas_dct1",
    "cheby_matrix",
]
