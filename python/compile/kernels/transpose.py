"""Blocked 2D local transpose as a Pallas kernel (the STRIDE1 path).

The paper's STRIDE1 option performs an explicit cache-blocked local memory
transpose so the FFT library always sees unit-stride data.  The TPU
analogue tiles the matrix into square VMEM blocks: each grid step reads
tile (i, j), transposes it in-register, and writes tile (j, i).  BlockSpec
expresses the HBM<->VMEM schedule that the paper expressed with loop
blocking for L2 cache.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _transpose_kernel(x_ref, o_ref):
    o_ref[...] = x_ref[...].T


def pallas_transpose_2d(x, *, block: int = 128):
    """Transpose a (R, C) array via square VMEM tiles.

    ``block`` is clamped to divide both dimensions; a (block, block) f32
    tile pair costs 2*block^2*4 bytes of VMEM (128 -> 128 KiB), far under
    budget, so the schedule is bandwidth-bound as expected for transposes.
    """
    r, c = x.shape
    br = min(block, r)
    while r % br != 0:
        br -= 1
    bc = min(block, c)
    while c % bc != 0:
        bc -= 1
    grid = (r // br, c // bc)
    return pl.pallas_call(
        _transpose_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((br, bc), lambda i, j: (i, j))],
        out_specs=pl.BlockSpec((bc, br), lambda i, j: (j, i)),
        out_shape=jax.ShapeDtypeStruct((c, r), x.dtype),
        interpret=True,
    )(x)
