"""Batched 1D DFT as Pallas matmul kernels (the TPU adaptation of FFTW).

The paper's per-task hot spot is "1D FFT over many grid lines".  On a CPU
cluster that is a strided FFTW call; on a TPU the idiomatic formulation is a
*matrix multiply with the DFT matrix*, which feeds the MXU systolic array:

    Y[B, N] = X[B, N] @ F_N,      (F_N)_{jk} = exp(-2*pi*i*j*k/N)

Complex data is carried as separate (re, im) planes, so the complex matmul
is four real matmuls — every flop is MXU-eligible.  For larger N the
four-step factorisation N = N1*N2 keeps the operands small enough for VMEM
while staying matmul-shaped (see ``pallas_dft_four_step``).

Batch tiling: the batch dimension is cut into blocks of ``block_b`` lines;
each Pallas grid step stages one (block_b, N) tile plus the (N, N) DFT
matrix in VMEM, multiplies on the MXU, and writes the tile back.  This is
the HBM<->VMEM analogue of the paper's cache loop-blocking.

VMEM footprint per grid step (f32): block_b*N*2 (in re+im) + N*N*2 (matrix)
+ block_b*N*2 (out) floats.  For N=1024, block_b=256: ~10.5 MiB — under the
16 MiB VMEM budget documented in DESIGN.md §Perf.
"""

import functools
import math

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl


def dft_matrices(n: int, *, inverse: bool = False, dtype=jnp.float32):
    """Real and imaginary parts of the NxN DFT matrix.

    Forward:  F_{jk} = cos(2 pi j k / n) - i sin(2 pi j k / n)
    Inverse uses +i and is NOT normalised (caller divides by n), matching
    both numpy's ``ifft * n`` and the Rust engine's convention.
    """
    j = np.arange(n)[:, None]
    k = np.arange(n)[None, :]
    ang = 2.0 * np.pi * j * k / n
    sign = 1.0 if inverse else -1.0
    fr = np.cos(ang)
    fi = sign * np.sin(ang)
    return jnp.asarray(fr, dtype=dtype), jnp.asarray(fi, dtype=dtype)


def _pick_block_b(batch: int, n: int) -> int:
    """Largest batch tile that keeps the working set under ~8 MiB of VMEM."""
    budget = 8 * 1024 * 1024 // 4  # f32 words
    mat = 2 * n * n
    per_line = 4 * n  # in re+im and out re+im
    if mat >= budget:
        return 1
    blk = max(1, (budget - mat) // max(per_line, 1))
    blk = min(blk, batch, 512)
    # Round down to a divisor of batch so the grid tiles exactly.
    while batch % blk != 0:
        blk -= 1
    return max(blk, 1)


def _cmatmul_kernel(xr_ref, xi_ref, fr_ref, fi_ref, or_ref, oi_ref):
    """One batch tile of the complex matmul (four real MXU matmuls)."""
    xr = xr_ref[...]
    xi = xi_ref[...]
    fr = fr_ref[...]
    fi = fi_ref[...]
    or_ref[...] = xr @ fr - xi @ fi
    oi_ref[...] = xr @ fi + xi @ fr


def _batched_cmatmul(xr, xi, fr, fi, *, block_b=None):
    """(B,N) complex times (N,M) complex -> (B,M) complex, Pallas-tiled.

    When one batch block covers the whole array the kernel is lowered
    WITHOUT a grid: interpret-mode grids become HLO `while` loops, which
    the AOT consumer (xla_extension 0.5.1 behind the Rust `xla` crate)
    executes incorrectly — and every AOT stage shape fits one block anyway
    (DESIGN.md §Hardware-Adaptation documents the VMEM budget math).
    """
    b, n = xr.shape
    m = fr.shape[1]
    blk = block_b or _pick_block_b(b, max(n, m))
    out_shape = (
        jax.ShapeDtypeStruct((b, m), xr.dtype),
        jax.ShapeDtypeStruct((b, m), xr.dtype),
    )
    if blk >= b:
        # Single block: whole operands staged at once, no grid loop.
        return pl.pallas_call(
            _cmatmul_kernel,
            out_shape=out_shape,
            interpret=True,
        )(xr, xi, fr, fi)
    grid = (b // blk,)
    spec_x = pl.BlockSpec((blk, n), lambda i: (i, 0))
    spec_f = pl.BlockSpec((n, m), lambda i: (0, 0))
    spec_o = pl.BlockSpec((blk, m), lambda i: (i, 0))
    return pl.pallas_call(
        _cmatmul_kernel,
        grid=grid,
        in_specs=[spec_x, spec_x, spec_f, spec_f],
        out_specs=(spec_o, spec_o),
        out_shape=out_shape,
        interpret=True,
    )(xr, xi, fr, fi)


def pallas_dft_c2c(xr, xi, *, inverse: bool = False, block_b=None):
    """Batched complex-to-complex DFT over the last axis of (B, N) planes.

    Inverse is unnormalised (multiply by 1/N yourself), matching the Rust
    ``fft::`` engine so artifacts and native paths agree bit-for-bit in
    convention.
    """
    n = xr.shape[-1]
    fr, fi = dft_matrices(n, inverse=inverse, dtype=xr.dtype)
    return _batched_cmatmul(xr, xi, fr, fi, block_b=block_b)


def pallas_dft_r2c(x, *, block_b=None):
    """Batched real-to-complex DFT: (B, N) real -> (B, N//2+1) complex.

    Exploits conjugate symmetry by multiplying with only the first N//2+1
    columns of the DFT matrix — output matches ``np.fft.rfft``.  The packed
    width (N+2)/2 is exactly Table 1's R2C output dimension.
    """
    b, n = x.shape
    h = n // 2 + 1
    fr, fi = dft_matrices(n, inverse=False, dtype=x.dtype)
    fr = fr[:, :h]
    fi = fi[:, :h]
    zeros = jnp.zeros_like(x)
    return _batched_cmatmul(x, zeros, fr, fi, block_b=block_b)


def pallas_dft_c2r(yr, yi, *, block_b=None):
    """Batched complex-to-real inverse: (B, N//2+1) -> (B, N) real, unnormalised.

    Reconstructs the full spectrum from the half-complex packing using
    conjugate symmetry, then applies the inverse DFT matrix; only the real
    output plane is returned.  Matches ``np.fft.irfft(y) * N``.
    """
    b, h = yr.shape
    n = 2 * (h - 1)
    # Unpack half-complex -> full spectrum (conjugate symmetry).
    mid_r = yr[:, 1:-1]
    mid_i = yi[:, 1:-1]
    full_r = jnp.concatenate([yr, mid_r[:, ::-1]], axis=1)
    full_i = jnp.concatenate([yi, -mid_i[:, ::-1]], axis=1)
    fr, fi = dft_matrices(n, inverse=True, dtype=yr.dtype)
    out_r, _ = _batched_cmatmul(full_r, full_i, fr, fi, block_b=block_b)
    return out_r


# ---------------------------------------------------------------------------
# Four-step factorisation: N = N1 * N2, all arithmetic stays matmul-shaped.
# ---------------------------------------------------------------------------


def _factor_pair(n: int):
    """Split n = n1 * n2 with n1 <= n2 as square as possible."""
    n1 = int(math.isqrt(n))
    while n % n1 != 0:
        n1 -= 1
    return n1, n // n1


@functools.partial(jax.jit, static_argnames=("inverse",))
def pallas_dft_four_step(xr, xi, *, inverse: bool = False):
    """Batched C2C DFT via the four-step algorithm, Pallas matmuls throughout.

    For X[b, n] with n = n1*n2 viewed as X[b, n1, n2] (row-major, so the
    original index is j = j1*n2 + j2), the Cooley-Tukey split with output
    index k = k1 + n1*k2 is:

      1. DFT of length n1 along the j1 axis       (matmul with F_{n1})
      2. twiddle multiply by exp(-+ 2 pi i j2 k1 / n)
      3. DFT of length n2 along the j2 axis       (matmul with F_{n2})
      4. permute (k1, k2) -> row-major k2-major layout = k1 + n1*k2

    Keeps every operand O(n^{1/2}) wide so the DFT matrices fit VMEM even
    for n where the direct NxN matrix would not.
    """
    b, n = xr.shape
    n1, n2 = _factor_pair(n)
    dtype = xr.dtype
    sign = 1.0 if inverse else -1.0

    # Step 1: DFT_{n1} along j1. Bring j1 innermost for the matmul.
    xr3 = xr.reshape(b, n1, n2).transpose(0, 2, 1).reshape(b * n2, n1)
    xi3 = xi.reshape(b, n1, n2).transpose(0, 2, 1).reshape(b * n2, n1)
    s1r, s1i = pallas_dft_c2c(xr3, xi3, inverse=inverse)
    s1r = s1r.reshape(b, n2, n1)  # axes (b, j2, k1)
    s1i = s1i.reshape(b, n2, n1)

    # Step 2: twiddle by exp(sign * 2 pi i * j2 * k1 / n).
    j2 = np.arange(n2)[:, None]
    k1 = np.arange(n1)[None, :]
    ang = 2.0 * np.pi * j2 * k1 / n
    twr = jnp.asarray(np.cos(ang), dtype=dtype)[None, :, :]
    twi = jnp.asarray(sign * np.sin(ang), dtype=dtype)[None, :, :]
    tr = s1r * twr - s1i * twi
    ti = s1r * twi + s1i * twr

    # Step 3: DFT_{n2} along j2. Bring j2 innermost: (b, k1, j2).
    tr = tr.transpose(0, 2, 1).reshape(b * n1, n2)
    ti = ti.transpose(0, 2, 1).reshape(b * n1, n2)
    s3r, s3i = pallas_dft_c2c(tr, ti, inverse=inverse)
    s3r = s3r.reshape(b, n1, n2)  # axes (b, k1, k2)
    s3i = s3i.reshape(b, n1, n2)

    # Step 4: k = k1 + n1*k2 -> row-major layout must be (b, k2, k1).
    out_r = s3r.transpose(0, 2, 1).reshape(b, n)
    out_i = s3i.transpose(0, 2, 1).reshape(b, n)
    return out_r, out_i
