"""Pure-jnp correctness oracles for the L1 Pallas kernels.

Every Pallas kernel has a reference here built only from ``jnp.fft`` /
dense numpy math.  pytest asserts allclose(kernel, ref) across shapes and
dtypes (hypothesis sweeps in python/tests/).
"""

import jax.numpy as jnp
import numpy as np


def ref_dft_c2c(xr, xi, *, inverse: bool = False):
    """Unnormalised batched DFT over the last axis, as (re, im) planes."""
    x = xr.astype(jnp.complex128) + 1j * xi.astype(jnp.complex128)
    y = jnp.fft.ifft(x, axis=-1) * x.shape[-1] if inverse else jnp.fft.fft(x, axis=-1)
    return jnp.real(y), jnp.imag(y)


def ref_dft_r2c(x):
    """np.fft.rfft equivalent returning (re, im)."""
    y = jnp.fft.rfft(x.astype(jnp.float64), axis=-1)
    return jnp.real(y), jnp.imag(y)


def ref_dft_c2r(yr, yi):
    """Unnormalised inverse of rfft: irfft(y) * N."""
    y = yr.astype(jnp.complex128) + 1j * yi.astype(jnp.complex128)
    n = 2 * (y.shape[-1] - 1)
    return jnp.fft.irfft(y, n=n, axis=-1) * n


def ref_dct1(x):
    """DCT-I, scipy type-1 unnormalised convention (see kernels/cheby.py)."""
    x64 = np.asarray(x, dtype=np.float64)
    n = x64.shape[-1]
    j = np.arange(n)[:, None]
    k = np.arange(n)[None, :]
    c = 2.0 * np.cos(np.pi * j * k / (n - 1))
    c[0, :] = 1.0
    c[n - 1, :] = (-1.0) ** np.arange(n)
    return x64 @ c


def ref_transpose(x):
    return jnp.transpose(x)


def ref_fft3d_r2c(x):
    """Full 3D R2C transform with the X axis *last* (stride-1) — the oracle
    for the composed per-stage pipeline (rust integration uses the same
    axis convention: transform axis is always innermost)."""
    return jnp.fft.rfftn(x.astype(jnp.float64), axes=(0, 1, 2))
