"""Build-time compile package: L2 JAX model + L1 Pallas kernels + AOT emitter.

Nothing in this package is imported at runtime; ``make artifacts`` runs
``python -m compile.aot`` once and the Rust binary is self-contained after.
"""
