"""L2: the per-task compute stages of the parallel 3D FFT, in JAX.

The distributed algorithm (L3, Rust) owns the two parallel transposes; what
each rank computes between them is a *batched 1D transform over the
innermost (stride-1) axis* of its local pencil.  Each stage below is a pure
function over 2D (batch, n) planes — the Rust side flattens the two
non-transform pencil dimensions into ``batch``.  All stages call the L1
Pallas kernels, so the lowered HLO contains the MXU-shaped matmul DFTs.

Stage inventory (mirrors the paper's Fig. 2 pipeline):

  stage_x_r2c : real X-pencil lines    (B, Nx)      -> (re, im) (B, Nx/2+1)
  stage_c2c_fwd / stage_c2c_bwd : complex Y-/Z-pencil lines (B, N) -> (B, N)
  stage_x_c2r : half-complex X lines   (B, Nx/2+1)  -> real (B, Nx), unnormalised
  stage_cheby : Chebyshev (DCT-I) third-dimension transform (B, Nz) -> (B, Nz)

The inverse stages are unnormalised; L3 applies the single 1/(Nx*Ny*Nz)
factor once at the end of a backward transform, exactly like FFTW/P3DFFT.
"""

import jax
import jax.numpy as jnp

from .kernels import (
    pallas_dft_c2c,
    pallas_dft_r2c,
    pallas_dft_c2r,
    pallas_dct1,
)

# Above this transform length the four-step factorisation is used instead of
# the direct DFT matmul (VMEM footprint math in DESIGN.md §Perf).
FOUR_STEP_THRESHOLD = 1024


def stage_x_r2c(x):
    """Forward stage 1: real-to-complex DFT over X lines."""
    return pallas_dft_r2c(x)


def stage_c2c_fwd(xr, xi):
    """Forward stages 2-3: complex-to-complex DFT over Y or Z lines."""
    return pallas_dft_c2c(xr, xi, inverse=False)


def stage_c2c_bwd(xr, xi):
    """Backward stages 1-2: unnormalised inverse C2C DFT."""
    return pallas_dft_c2c(xr, xi, inverse=True)


def stage_x_c2r(yr, yi):
    """Backward stage 3: half-complex to real, unnormalised."""
    return pallas_dft_c2r(yr, yi)


def stage_cheby(x):
    """Chebyshev (DCT-I) transform for the wall-bounded third dimension."""
    return pallas_dct1(x)


def local_fft3d_r2c(x):
    """Whole 3D R2C on one task's data (the P=1 degenerate case).

    Used by the e2e driver to validate the composed per-stage pipeline
    against a single fused HLO, and as the single-rank reference path.
    Input (Nz, Ny, Nx) real, output (re, im) of shape (Nz, Ny, Nx/2+1):
    transform axes innermost-first, matching the distributed pipeline.
    """
    nz, ny, nx = x.shape
    h = nx // 2 + 1
    # X transform (innermost).
    xr, xi = pallas_dft_r2c(x.reshape(nz * ny, nx))
    xr = xr.reshape(nz, ny, h)
    xi = xi.reshape(nz, ny, h)
    # Y transform: bring Y innermost.
    xr = jnp.transpose(xr, (0, 2, 1)).reshape(nz * h, ny)
    xi = jnp.transpose(xi, (0, 2, 1)).reshape(nz * h, ny)
    yr, yi = pallas_dft_c2c(xr, xi, inverse=False)
    yr = yr.reshape(nz, h, ny)
    yi = yi.reshape(nz, h, ny)
    # Z transform: bring Z innermost.
    yr = jnp.transpose(yr, (1, 2, 0)).reshape(h * ny, nz)
    yi = jnp.transpose(yi, (1, 2, 0)).reshape(h * ny, nz)
    zr, zi = pallas_dft_c2c(yr, yi, inverse=False)
    # Output layout (h, ny, nz) -> transpose back to (nz, ny, h).
    zr = jnp.transpose(zr.reshape(h, ny, nz), (2, 1, 0))
    zi = jnp.transpose(zi.reshape(h, ny, nz), (2, 1, 0))
    return zr, zi


# ---------------------------------------------------------------------------
# AOT stage registry: name -> (builder of jittable fn, example-args builder).
# Shapes are static per artifact; aot.py instantiates one HLO per
# (stage, batch, n) the Rust plan will request.
# ---------------------------------------------------------------------------


def make_stage_fn(stage: str):
    """Return a jittable function-of-arrays for the named stage."""
    if stage == "x_r2c":
        return lambda x: stage_x_r2c(x)
    if stage == "c2c_fwd":
        return lambda xr, xi: stage_c2c_fwd(xr, xi)
    if stage == "c2c_bwd":
        return lambda xr, xi: stage_c2c_bwd(xr, xi)
    if stage == "x_c2r":
        return lambda yr, yi: (stage_x_c2r(yr, yi),)
    if stage == "cheby":
        return lambda x: (stage_cheby(x),)
    if stage == "fft3d_r2c":
        return lambda x: local_fft3d_r2c(x)
    raise ValueError(f"unknown stage {stage!r}")


def stage_example_args(stage: str, batch: int, n: int, dtype=jnp.float32):
    """ShapeDtypeStructs for lowering the named stage."""
    f = jax.ShapeDtypeStruct
    h = n // 2 + 1
    if stage == "x_r2c":
        return (f((batch, n), dtype),)
    if stage in ("c2c_fwd", "c2c_bwd"):
        return (f((batch, n), dtype), f((batch, n), dtype))
    if stage == "x_c2r":
        return (f((batch, h), dtype), f((batch, h), dtype))
    if stage == "cheby":
        return (f((batch, n), dtype),)
    if stage == "fft3d_r2c":
        # batch is (nz, ny) here; n is nx. Cube grids only for this artifact.
        return (f((n, n, n), dtype),)
    raise ValueError(f"unknown stage {stage!r}")
