import jax

# f64 artifacts and f64 oracles need x64 mode; set it before any test runs.
jax.config.update("jax_enable_x64", True)
