"""L1 DFT kernels vs the pure-jnp oracle (the core correctness signal)."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_allclose

from compile.kernels import (
    pallas_dft_c2c,
    pallas_dft_r2c,
    pallas_dft_c2r,
    pallas_dft_four_step,
)
from compile.kernels.ref import ref_dft_c2c, ref_dft_r2c, ref_dft_c2r

RNG = np.random.default_rng(12345)


def _rand(b, n, dtype=np.float64):
    return (RNG.standard_normal((b, n)).astype(dtype),
            RNG.standard_normal((b, n)).astype(dtype))


@pytest.mark.parametrize("n", [2, 4, 8, 16, 17, 32, 48, 64, 100, 128])
@pytest.mark.parametrize("b", [1, 3, 8])
def test_c2c_forward_matches_fft(b, n):
    xr, xi = _rand(b, n)
    got_r, got_i = pallas_dft_c2c(jnp.asarray(xr), jnp.asarray(xi))
    exp_r, exp_i = ref_dft_c2c(xr, xi)
    assert_allclose(got_r, exp_r, rtol=1e-9, atol=1e-9 * n)
    assert_allclose(got_i, exp_i, rtol=1e-9, atol=1e-9 * n)


@pytest.mark.parametrize("n", [4, 16, 48, 64])
def test_c2c_inverse_matches_unnormalised_ifft(n):
    xr, xi = _rand(5, n)
    got_r, got_i = pallas_dft_c2c(jnp.asarray(xr), jnp.asarray(xi), inverse=True)
    exp_r, exp_i = ref_dft_c2c(xr, xi, inverse=True)
    assert_allclose(got_r, exp_r, rtol=1e-9, atol=1e-9 * n)
    assert_allclose(got_i, exp_i, rtol=1e-9, atol=1e-9 * n)


@pytest.mark.parametrize("n", [4, 16, 48, 64])
def test_c2c_roundtrip_is_identity_times_n(n):
    xr, xi = _rand(4, n)
    fr, fi = pallas_dft_c2c(jnp.asarray(xr), jnp.asarray(xi))
    br, bi = pallas_dft_c2c(fr, fi, inverse=True)
    assert_allclose(np.asarray(br) / n, xr, rtol=1e-9, atol=1e-9 * n)
    assert_allclose(np.asarray(bi) / n, xi, rtol=1e-9, atol=1e-9 * n)


@pytest.mark.parametrize("n", [2, 4, 8, 16, 32, 48, 64, 100])
def test_r2c_matches_rfft(n):
    x, _ = _rand(6, n)
    got_r, got_i = pallas_dft_r2c(jnp.asarray(x))
    exp_r, exp_i = ref_dft_r2c(x)
    assert got_r.shape == (6, n // 2 + 1)
    assert_allclose(got_r, exp_r, rtol=1e-9, atol=1e-9 * n)
    assert_allclose(got_i, exp_i, rtol=1e-9, atol=1e-9 * n)


def test_r2c_dc_and_nyquist_are_real():
    x, _ = _rand(3, 16)
    got_r, got_i = pallas_dft_r2c(jnp.asarray(x))
    assert_allclose(np.asarray(got_i)[:, 0], 0.0, atol=1e-9)
    assert_allclose(np.asarray(got_i)[:, -1], 0.0, atol=1e-9)


@pytest.mark.parametrize("n", [4, 8, 16, 32, 64])
def test_c2r_matches_unnormalised_irfft(n):
    x, _ = _rand(4, n)
    yr, yi = ref_dft_r2c(x)
    got = pallas_dft_c2r(jnp.asarray(np.asarray(yr)), jnp.asarray(np.asarray(yi)))
    exp = ref_dft_c2r(yr, yi)
    assert_allclose(got, exp, rtol=1e-9, atol=1e-9 * n)


@pytest.mark.parametrize("n", [4, 8, 16, 32, 64, 100])
def test_r2c_c2r_roundtrip(n):
    x, _ = _rand(4, n)
    yr, yi = pallas_dft_r2c(jnp.asarray(x))
    back = pallas_dft_c2r(yr, yi)
    assert_allclose(np.asarray(back) / n, x, rtol=1e-9, atol=1e-9 * n)


@pytest.mark.parametrize("n", [16, 36, 64, 144, 256])
def test_four_step_matches_direct(n):
    xr, xi = _rand(3, n)
    got_r, got_i = pallas_dft_four_step(jnp.asarray(xr), jnp.asarray(xi))
    exp_r, exp_i = ref_dft_c2c(xr, xi)
    assert_allclose(got_r, exp_r, rtol=1e-8, atol=1e-8 * n)
    assert_allclose(got_i, exp_i, rtol=1e-8, atol=1e-8 * n)


@pytest.mark.parametrize("n", [16, 64, 144])
def test_four_step_inverse(n):
    xr, xi = _rand(2, n)
    got_r, got_i = pallas_dft_four_step(
        jnp.asarray(xr), jnp.asarray(xi), inverse=True)
    exp_r, exp_i = ref_dft_c2c(xr, xi, inverse=True)
    assert_allclose(got_r, exp_r, rtol=1e-8, atol=1e-8 * n)
    assert_allclose(got_i, exp_i, rtol=1e-8, atol=1e-8 * n)


# ---------------------------------------------------------------------------
# Hypothesis sweeps: shapes, dtypes, linearity/shift invariants.
# ---------------------------------------------------------------------------


@settings(max_examples=25, deadline=None)
@given(b=st.integers(1, 12), n=st.integers(2, 96),
       dtype=st.sampled_from([np.float32, np.float64]))
def test_hyp_c2c_any_shape_dtype(b, n, dtype):
    xr = RNG.standard_normal((b, n)).astype(dtype)
    xi = RNG.standard_normal((b, n)).astype(dtype)
    got_r, got_i = pallas_dft_c2c(jnp.asarray(xr), jnp.asarray(xi))
    exp_r, exp_i = ref_dft_c2c(xr, xi)
    tol = 1e-3 * n if dtype == np.float32 else 1e-9 * n
    assert got_r.dtype == dtype
    assert_allclose(got_r, exp_r, rtol=0, atol=tol)
    assert_allclose(got_i, exp_i, rtol=0, atol=tol)


@settings(max_examples=25, deadline=None)
@given(b=st.integers(1, 8), n=st.sampled_from([2, 4, 6, 8, 12, 16, 20, 32, 64]),
       dtype=st.sampled_from([np.float32, np.float64]))
def test_hyp_r2c_any_shape_dtype(b, n, dtype):
    x = RNG.standard_normal((b, n)).astype(dtype)
    got_r, got_i = pallas_dft_r2c(jnp.asarray(x))
    exp_r, exp_i = ref_dft_r2c(x)
    tol = 1e-3 * n if dtype == np.float32 else 1e-9 * n
    assert_allclose(got_r, exp_r, rtol=0, atol=tol)
    assert_allclose(got_i, exp_i, rtol=0, atol=tol)


@settings(max_examples=15, deadline=None)
@given(n=st.sampled_from([4, 8, 16, 32]))
def test_hyp_dft_linearity(n):
    xr, xi = _rand(2, n)
    yr, yi = _rand(2, n)
    a, b = 0.7, -1.3
    gr1, gi1 = pallas_dft_c2c(jnp.asarray(a * xr + b * yr),
                              jnp.asarray(a * xi + b * yi))
    xr1, xi1 = pallas_dft_c2c(jnp.asarray(xr), jnp.asarray(xi))
    yr1, yi1 = pallas_dft_c2c(jnp.asarray(yr), jnp.asarray(yi))
    assert_allclose(gr1, a * np.asarray(xr1) + b * np.asarray(yr1),
                    rtol=1e-9, atol=1e-9 * n)
    assert_allclose(gi1, a * np.asarray(xi1) + b * np.asarray(yi1),
                    rtol=1e-9, atol=1e-9 * n)


@settings(max_examples=15, deadline=None)
@given(n=st.sampled_from([8, 16, 32]), s=st.integers(1, 7))
def test_hyp_dft_shift_theorem(n, s):
    """DFT(roll(x, s))_k = DFT(x)_k * exp(-2 pi i s k / n)."""
    xr, xi = _rand(1, n)
    fr, fi = pallas_dft_c2c(jnp.asarray(xr), jnp.asarray(xi))
    sr, si = pallas_dft_c2c(jnp.asarray(np.roll(xr, s, axis=1)),
                            jnp.asarray(np.roll(xi, s, axis=1)))
    k = np.arange(n)
    pr, pi = np.cos(2 * np.pi * s * k / n), -np.sin(2 * np.pi * s * k / n)
    exp_r = np.asarray(fr) * pr - np.asarray(fi) * pi
    exp_i = np.asarray(fr) * pi + np.asarray(fi) * pr
    assert_allclose(sr, exp_r, rtol=1e-9, atol=1e-9 * n)
    assert_allclose(si, exp_i, rtol=1e-9, atol=1e-9 * n)


@settings(max_examples=10, deadline=None)
@given(n=st.sampled_from([8, 16, 32]))
def test_hyp_parseval(n):
    xr, xi = _rand(1, n)
    fr, fi = pallas_dft_c2c(jnp.asarray(xr), jnp.asarray(xi))
    e_time = np.sum(xr**2 + xi**2)
    e_freq = np.sum(np.asarray(fr) ** 2 + np.asarray(fi) ** 2) / n
    assert_allclose(e_freq, e_time, rtol=1e-9)
