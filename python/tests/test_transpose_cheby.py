"""Pallas blocked transpose + Chebyshev/DCT-I kernels vs oracles."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_allclose

from compile.kernels import pallas_transpose_2d, pallas_dct1, cheby_matrix
from compile.kernels.ref import ref_dct1

RNG = np.random.default_rng(999)


@pytest.mark.parametrize("r,c", [(1, 1), (4, 4), (8, 3), (3, 8), (128, 128),
                                 (256, 64), (100, 30), (17, 129)])
def test_transpose_exact(r, c):
    x = RNG.standard_normal((r, c))
    got = pallas_transpose_2d(jnp.asarray(x))
    assert got.shape == (c, r)
    assert np.array_equal(np.asarray(got), x.T)


@settings(max_examples=30, deadline=None)
@given(r=st.integers(1, 200), c=st.integers(1, 200),
       block=st.sampled_from([8, 32, 128]),
       dtype=st.sampled_from([np.float32, np.float64]))
def test_hyp_transpose_any_shape(r, c, block, dtype):
    x = RNG.standard_normal((r, c)).astype(dtype)
    got = pallas_transpose_2d(jnp.asarray(x), block=block)
    assert got.dtype == dtype
    assert np.array_equal(np.asarray(got), x.T)


def test_transpose_involution():
    x = RNG.standard_normal((48, 96))
    assert np.array_equal(
        np.asarray(pallas_transpose_2d(pallas_transpose_2d(jnp.asarray(x)))), x)


@pytest.mark.parametrize("n", [3, 4, 5, 9, 17, 33, 65])
@pytest.mark.parametrize("b", [1, 4])
def test_dct1_matches_ref(b, n):
    x = RNG.standard_normal((b, n))
    got = pallas_dct1(jnp.asarray(x))
    assert_allclose(got, ref_dct1(x), rtol=1e-9, atol=1e-9 * n)


@pytest.mark.parametrize("n", [5, 9, 17, 33])
def test_dct1_involution(n):
    """DCT-I composed with itself is 2(N-1) * identity."""
    x = RNG.standard_normal((3, n))
    twice = pallas_dct1(pallas_dct1(jnp.asarray(x)))
    assert_allclose(np.asarray(twice) / (2 * (n - 1)), x,
                    rtol=1e-9, atol=1e-9 * n)


def test_cheby_matrix_symmetric_rows():
    """Row 0 weight 1, last row alternating signs — the DCT-I endpoints."""
    c = np.asarray(cheby_matrix(9, dtype=jnp.float64))
    assert_allclose(c[0], np.ones(9))
    assert_allclose(c[8], (-1.0) ** np.arange(9))


@settings(max_examples=20, deadline=None)
@given(n=st.integers(3, 64), b=st.integers(1, 6))
def test_hyp_dct1_recovers_chebyshev_coeffs(n, b):
    """A signal built from known Chebyshev polynomials on the Gauss-Lobatto
    grid must transform to exactly those coefficients."""
    ks = RNG.integers(0, n, size=3)
    amps = RNG.standard_normal(3)
    j = np.arange(n)
    xgrid = np.cos(np.pi * j / (n - 1))  # Gauss-Lobatto points
    sig = np.zeros(n)
    for k, a in zip(ks, amps):
        sig += a * np.cos(k * np.arccos(np.clip(xgrid, -1, 1)))
    x = np.tile(sig, (b, 1))
    y = np.asarray(pallas_dct1(jnp.asarray(x)))
    # Invert analytically: coefficient c_k = y_k / (N-1), halved at endpoints.
    coef = y[0] / (n - 1)
    coef[0] /= 2.0
    coef[-1] /= 2.0
    expect = np.zeros(n)
    for k, a in zip(ks, amps):
        expect[k] += a
    assert_allclose(coef, expect, atol=1e-8)
