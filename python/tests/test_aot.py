"""AOT emitter: decomposition math, HLO text emission, manifest format."""

import os

import pytest

from compile import aot


def test_block_sizes_even():
    assert aot.block_sizes(32, 4) == [8, 8, 8, 8]


def test_block_sizes_uneven_remainder_to_low_ranks():
    assert aot.block_sizes(17, 4) == [5, 4, 4, 4]
    assert aot.block_sizes(7, 3) == [3, 2, 2]
    assert sum(aot.block_sizes(256, 24)) == 256  # paper's 256^3-on-24 example


def test_stage_set_covers_all_stages():
    combos = aot.stage_set(32, 32, 32, 2, 2)
    stages = {s for s, _, _ in combos}
    assert stages == {"x_r2c", "x_c2r", "c2c_fwd", "c2c_bwd", "cheby"}


def test_stage_set_even_grid_batches():
    combos = dict()
    for s, b, n in aot.stage_set(32, 32, 32, 2, 2):
        combos.setdefault(s, set()).add((b, n))
    # X-pencil: (ny/2)*(nz/2) = 256 lines of length 32.
    assert combos["x_r2c"] == {(256, 32)}
    # Y-pencil: h=17 splits 9+8 over M1=2 -> batches 9*16 and 8*16.
    assert combos["c2c_fwd"] >= {(144, 32), (128, 32)}


def test_stage_set_uneven_matches_rust_convention():
    combos = aot.stage_set(20, 20, 20, 3, 2)
    # ny=20 over m1=3 -> [7,7,6]; nz=20 over m2=2 -> [10,10].
    xbatches = {b for s, b, n in combos if s == "x_r2c"}
    assert xbatches == {70, 60}


@pytest.mark.parametrize("stage", ["x_r2c", "c2c_fwd", "x_c2r", "cheby"])
def test_lower_stage_emits_hlo_text(stage):
    text = aot.lower_stage(stage, 4, 8, "f32")
    assert "ENTRY" in text
    assert "HloModule" in text


def test_lower_stage_f64(tmp_path):
    text = aot.lower_stage("c2c_fwd", 2, 4, "f64")
    assert "f64" in text


def test_manifest_written(tmp_path, monkeypatch):
    import sys
    monkeypatch.setattr(sys, "argv", [
        "aot", "--out-dir", str(tmp_path), "--grid", "8,8,8",
        "--pgrid", "1,1", "--dtypes", "f32", "--fused-cube", "0"])
    aot.main()
    manifest = (tmp_path / "manifest.txt").read_text().strip().splitlines()
    rows = [l.split("\t") for l in manifest if not l.startswith("#")]
    assert rows, "manifest should list artifacts"
    for row in rows:
        assert len(row) == 7
        assert os.path.exists(tmp_path / row[0])
