"""L2 stage graph: composed pipeline vs jnp.fft oracles, shape contracts."""

import jax.numpy as jnp
import numpy as np
import pytest
from numpy.testing import assert_allclose

from compile import model
from compile.kernels.ref import ref_fft3d_r2c

RNG = np.random.default_rng(777)


@pytest.mark.parametrize("n", [4, 8, 16])
def test_local_fft3d_matches_rfftn(n):
    x = RNG.standard_normal((n, n, n))
    got_r, got_i = model.local_fft3d_r2c(jnp.asarray(x))
    exp = np.asarray(ref_fft3d_r2c(x))
    assert got_r.shape == (n, n, n // 2 + 1)
    assert_allclose(got_r, exp.real, rtol=1e-8, atol=1e-8 * n**3)
    assert_allclose(got_i, exp.imag, rtol=1e-8, atol=1e-8 * n**3)


def test_local_fft3d_noncube_batch_axes():
    nz, ny, nx = 4, 8, 16
    x = RNG.standard_normal((nz, ny, nx))
    got_r, got_i = model.local_fft3d_r2c(jnp.asarray(x))
    exp = np.asarray(ref_fft3d_r2c(x))
    assert_allclose(got_r, exp.real, rtol=1e-8, atol=1e-6)
    assert_allclose(got_i, exp.imag, rtol=1e-8, atol=1e-6)


def test_forward_backward_pipeline_roundtrip():
    """stage_x_r2c -> c2c -> c2c -> inverse chain recovers input * Nx*Ny*Nz
    (the P3DFFT normalisation convention)."""
    n = 8
    h = n // 2 + 1
    x = RNG.standard_normal((n * n, n))
    yr, yi = model.stage_x_r2c(jnp.asarray(x))
    # The complex stages here act on the packed axis of length h.
    fr, fi = model.stage_c2c_fwd(yr, yi)
    br, bi = model.stage_c2c_bwd(fr, fi)
    back = model.stage_x_c2r(br / h, bi / h)
    assert_allclose(np.asarray(back) / n, x, rtol=1e-8, atol=1e-8)


@pytest.mark.parametrize("stage,n_in,n_out", [
    ("x_r2c", 1, 2), ("c2c_fwd", 2, 2), ("c2c_bwd", 2, 2),
    ("x_c2r", 2, 1), ("cheby", 1, 1),
])
def test_stage_registry_arity(stage, n_in, n_out):
    fn = model.make_stage_fn(stage)
    args = model.stage_example_args(stage, 4, 8, dtype=jnp.float64)
    assert len(args) == n_in
    concrete = [jnp.zeros(a.shape, a.dtype) for a in args]
    out = fn(*concrete)
    assert len(out) == n_out


def test_stage_example_args_r2c_packing():
    (a,) = model.stage_example_args("x_r2c", 10, 32)
    assert a.shape == (10, 32)
    yr, yi = model.stage_example_args("x_c2r", 10, 32)
    assert yr.shape == (10, 17)  # (N+2)/2 packed width per Table 1
